"""Paged KV cache: a shared block pool read through per-sequence block
tables.

The ring cache (`make_attn_cache` / `make_mla_cache`) gives every batch
row a contiguous ``capacity``-slot strip, so short requests strand memory
and identical prompt prefixes are stored once *per row*.  The paged
layout replaces the per-row strip with a pool of fixed-size blocks:

* per attention layer, K/V/pos live in pools with a leading *block* axis
  — ``k``: [NB, bs, Hkv, D] (MLA: ``ckv`` [NB, bs, R], ``krope``
  [NB, bs, Dr]), ``pos``: [NB, bs] (-1 = invalid slot);
* each layer entry also carries the (shared) block table ``bt``:
  [B, MB] int32 of pool block ids, -1 = unallocated.  Token position
  ``p`` of sequence ``b`` lives at ``(bt[b, p // bs], p % bs)``;
* block ids are identical across layers (one logical table), so the
  host-side :class:`repro.serving.block_manager.BlockManager` does all
  allocation/refcount/prefix bookkeeping once per sequence.

A paged layer entry is recognized by ``"bt" in entry`` — everything else
(`scatter_kv`, the attention backends, `forward`) dispatches on that.

Prefix sharing is copy-on-write at block granularity: a block is keyed by
the hash of the *cumulative* prompt prefix it completes (K/V at position
``p`` depend only on tokens ``<= p`` and model params, so equal prefixes
yield bit-identical blocks), shared blocks carry a refcount, and a write
into a block with refcount > 1 must be preceded by a copy
(:func:`copy_blocks`).  In the serving engines shared blocks are always
*fully inside* the prompt while decode writes start at the prompt end, so
the engines never trigger CoW — ``fork`` (sequence cloning) is where it
bites, and the property tests exercise it directly.

Sliding-window layers are paged at full length (no ``min(capacity,
window)`` cap): position->block indexing must stay injective, and the
kernel's block skip already prunes out-of-window blocks from the read
path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import kvsan

from .config import ATTN, MLA, ModelConfig, layer_specs

DEFAULT_BLOCK_SIZE = 16

# Pool-leaf names per paged layer kind ("pos" and "bt" ride along both).
_POOL_KEYS = ("k", "v", "ckv", "krope", "pos")


def is_paged_entry(entry) -> bool:
    return isinstance(entry, dict) and "bt" in entry


def is_paged_cache(cache) -> bool:
    layers = cache.get("layers", cache.get("prefix", []))
    return any(is_paged_entry(e) for e in layers)


def num_seq_blocks(capacity: int, block_size: int) -> int:
    """Block-table width: blocks covering one sequence of ``capacity``."""
    return -(-capacity // block_size)


# ------------------------------------------------------------------ init
def make_paged_attn_cache(cfg: ModelConfig, batch, capacity, block_size,
                          num_blocks, dtype=jnp.float32):
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    MB = num_seq_blocks(capacity, block_size)
    return {
        "k": jnp.zeros((num_blocks, block_size, Hkv, Dh), dtype),
        "v": jnp.zeros((num_blocks, block_size, Hkv, Dh), dtype),
        "pos": jnp.full((num_blocks, block_size), -1, jnp.int32),
        "bt": jnp.full((batch, MB), -1, jnp.int32),
    }


def make_paged_mla_cache(cfg: ModelConfig, batch, capacity, block_size,
                         num_blocks, dtype=jnp.float32):
    m = cfg.mla
    MB = num_seq_blocks(capacity, block_size)
    return {
        "ckv": jnp.zeros((num_blocks, block_size, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((num_blocks, block_size, m.qk_rope_dim), dtype),
        "pos": jnp.full((num_blocks, block_size), -1, jnp.int32),
        "bt": jnp.full((batch, MB), -1, jnp.int32),
    }


# ------------------------------------------------------------------ views
def gather_view(bt, pos_pool, pools):
    """Dense per-sequence views of paged pool leaves — THE paged read
    indexing rule (one home for it; the ref backend and every dense
    oracle go through here).

    bt: [B, MB] block table (-1 unallocated); pos_pool: [NB, bs];
    pools: iterable of [NB, bs, ...] leaves (None passes through).
    Returns ``(gathered_pools, positions)``: leaves [B, MB*bs, ...] and
    positions [B, MB*bs] with -1 wherever the table row is unallocated
    (hole blocks clamp to pool block 0; their dead values are killed by
    the -1 positions)."""
    B, MB = bt.shape
    idx = jnp.maximum(bt, 0)
    outs = []
    for pool in pools:
        if pool is None:
            outs.append(None)
            continue
        g = pool[idx]                                 # [B, MB, bs, ...]
        outs.append(g.reshape((B, MB * pool.shape[1]) + pool.shape[2:]))
    pos = jnp.where((bt >= 0)[..., None], pos_pool[idx], -1)
    return outs, pos.reshape(B, -1)


def gather_pos(entry):
    """Per-sequence positions [B, MB*bs] read through the block table."""
    return gather_view(entry["bt"], entry["pos"], ())[1]


def gather_kv(entry, keys=("k", "v")):
    """Dense per-sequence views [B, MB*bs, ...] of a layer entry's pool
    leaves, plus positions."""
    outs, pos = gather_view(entry["bt"], entry["pos"],
                            [entry[k] for k in keys])
    return tuple(outs) + (pos,)


# ------------------------------------------------------------------ write
def _write_slots(entry, positions, accept_mask=None):
    """(block_id, offset) scatter coordinates for per-token writes.

    Invalid targets — masked tokens, negative positions, positions past
    the table span, unallocated table entries — are routed to the
    out-of-range block id NB so ``.at[...].set(mode="drop")`` drops them
    (the same OOB-slot trick the ring scatter uses)."""
    bt = entry["bt"]
    NB = entry["pos"].shape[0]
    bs = entry["pos"].shape[1]
    MB = bt.shape[1]
    valid = (positions >= 0) & (positions < MB * bs)
    if accept_mask is not None:
        valid &= accept_mask
    blk = jnp.where(valid, positions // bs, 0)
    bidx = jnp.arange(positions.shape[0])[:, None]
    bid = bt[bidx, blk]                               # [B, T]
    valid &= bid >= 0
    bid = jnp.where(valid, bid, NB)
    off = jnp.where(valid, positions % bs, 0)
    pos = jnp.where(valid, positions, -1)
    return bid, off, pos


def scatter_paged(entry, new_leaves: dict, positions, accept_mask=None):
    """Write per-token rows into the pools at ``positions``.

    ``new_leaves`` maps pool-leaf names ("k"/"v" or "ckv"/"krope") to
    [B, T, ...] arrays.  Returns the updated entry (bt unchanged)."""
    bid, off, pos = _write_slots(entry, positions, accept_mask)
    # trace-time sanitizer emit: attaches a host callback validating the
    # non-dropped writes when kvsan is active, emits nothing when off
    kvsan.emit_scatter_check(entry, bid, off)
    out = dict(entry)
    for key, val in new_leaves.items():
        out[key] = entry[key].at[bid, off].set(val, mode="drop")
    out["pos"] = entry["pos"].at[bid, off].set(pos, mode="drop")
    return out


# ------------------------------------------------------- admission splice
def _splice_entry(entry, row, table):
    """Write one paged layer's full table span from a prefilled ring row.

    Every allocated table block is written (unallocated tail entries are
    -1 and route to the OOB block id, so ``mode="drop"`` drops them).
    Ring rows may be window-capped and wrapped (sliding layers), so each
    target position is gathered from its ring slot and validated against
    the ring's own position record; invalid targets (past the prompt)
    are zeroed with pos -1."""
    bs = entry["pos"].shape[1]
    MB = entry["bt"].shape[1]
    NB = entry["pos"].shape[0]
    tpos = (jnp.arange(MB, dtype=jnp.int32)[:, None] * bs
            + jnp.arange(bs, dtype=jnp.int32)[None, :])     # [MB, bs]
    Cr = row["pos"].shape[1]                    # ring row capacity
    src_slot = tpos % Cr
    rpos = row["pos"][0, src_slot]                          # [MB, bs]
    valid = rpos == tpos
    ids = jnp.where(table >= 0, table, NB)      # OOB-drop unallocated
    e = dict(entry)
    for key in ("k", "v", "ckv", "krope"):
        if key not in entry:
            continue
        src = row[key][0, src_slot]                         # [MB, bs, ...]
        src = jnp.where(
            valid.reshape(valid.shape + (1,) * (src.ndim - 2)),
            src, 0.0).astype(entry[key].dtype)
        e[key] = entry[key].at[ids].set(src, mode="drop")
    e["pos"] = entry["pos"].at[ids].set(jnp.where(valid, tpos, -1),
                                        mode="drop")
    return e


def _splice_impl(cache, row_cache, slot, table, plen):
    out = dict(cache)
    new_layers = []
    for entry, row in zip(cache["layers"], row_cache["layers"]):
        if not is_paged_entry(entry):
            new_layers.append(jax.tree.map(
                lambda d, s: jax.lax.dynamic_update_slice_in_dim(
                    d, s.astype(d.dtype), slot, axis=0), entry, row))
            continue
        e = _splice_entry(entry, row, table)
        e["bt"] = entry["bt"].at[slot].set(table)
        new_layers.append(e)
    out["layers"] = new_layers
    out["length"] = cache["length"].at[slot].set(plen)
    return out


_splice_jit = jax.jit(_splice_impl)


def write_prefill_blocks(cfg: ModelConfig, cache, row_cache, slot: int,
                         block_ids, n_shared: int, plen: int):
    """Splice a freshly prefilled batch-1 *ring* row cache into the pool.

    ``block_ids`` (host ints) are the sequence's allocated pool blocks in
    table order.  The whole splice runs as ONE jitted program with
    shape-stable arguments (row caches are always full-capacity, the
    table is padded to the table span MB), so an admission costs one
    compiled dispatch instead of ~8 eager scatter ops per layer — the
    compile is paid once per engine.  Prefix-shared blocks
    (``block_ids[:n_shared]``) are re-written with this row's prefill
    content; that is a no-op by the prefix-sharing invariant (K/V at
    position ``p`` depend only on tokens ``<= p`` and the weights, and
    the forward is deterministic), and keeping the write makes the
    program independent of ``n_shared``.  Non-paged entries (recurrent
    SSM / RG-LRU state) are row-copied as in
    :func:`repro.models.model.write_cache_rows`.  Sets
    ``length[slot] = plen``."""
    del cfg, n_shared
    MB = next(e["bt"].shape[1] for e in cache["layers"]
              if is_paged_entry(e))
    table = np.full((MB,), -1, np.int32)
    table[:len(block_ids)] = np.asarray(block_ids, np.int32)
    pool = kvsan.pool_if_active()
    if pool is not None:
        pool.on_splice(slot, [int(b) for b in block_ids], plen)
    return _splice_jit(cache, row_cache, np.int32(slot),
                       jnp.asarray(table), np.int32(plen))


# ------------------------------------------------------- chunked prefill
def _begin_impl(cache, slot, table, start):
    out = dict(cache)
    out["layers"] = [
        dict(e, bt=e["bt"].at[slot].set(table)) if is_paged_entry(e) else e
        for e in cache["layers"]]
    out["length"] = cache["length"].at[slot].set(start)
    return out


_begin_jit = jax.jit(_begin_impl)


def begin_prefill_row(cache, slot: int, shared_ids, start: int):
    """Start a chunked prefill on ``slot``: point the table row at the
    prefix-shared blocks (their pool content is already valid — K/V at
    position p depend only on tokens <= p, so they are NOT recomputed)
    and set ``length[slot] = start`` (= ``len(shared_ids) * block_size``).
    The rest of the row is cleared to -1 so no stale table entry from a
    previous occupant is ever read.  One jitted dispatch, shape-stable."""
    MB = next(e["bt"].shape[1] for e in cache["layers"]
              if is_paged_entry(e))
    table = np.full((MB,), -1, np.int32)
    table[:len(shared_ids)] = np.asarray(shared_ids, np.int32)
    pool = kvsan.pool_if_active()
    if pool is not None:
        pool.on_set_row(slot, [int(b) for b in shared_ids])
    return _begin_jit(cache, np.int32(slot), jnp.asarray(table),
                      np.int32(start))


def _arm_impl(cache, slot, idxs, bids, clear_ids):
    out = dict(cache)
    new_layers = []
    for entry in cache["layers"]:
        if not is_paged_entry(entry):
            new_layers.append(entry)
            continue
        e = dict(entry)
        e["pos"] = entry["pos"].at[clear_ids].set(-1, mode="drop")
        e["bt"] = entry["bt"].at[slot, idxs].set(bids, mode="drop")
        new_layers.append(e)
    out["layers"] = new_layers
    return out


_arm_jit = jax.jit(_arm_impl)


def write_prefill_chunk(cache, slot: int, entries, clear_bids):
    """Arm one prefill chunk's target blocks so the fused chunk forward
    scatters its K/V *directly into the pool* (offset-aware: the chunk's
    positions route through the freshly installed table entries) — the
    dense ``row_cache`` splice is off the chunked serving hot path.

    ``entries`` is ``[(table_idx, block_id), ...]`` for the blocks this
    chunk's token span touches; ``clear_bids`` are the freshly-popped
    pool blocks whose stale ``pos`` records (from previous owners) must
    be invalidated before the chunk's causal read.  Both vectors are
    padded to the table span MB with out-of-range indices, so every call
    hits one compiled program regardless of chunk/entry counts."""
    MB = next(e["bt"].shape[1] for e in cache["layers"]
              if is_paged_entry(e))
    NB = next(e["pos"].shape[0] for e in cache["layers"]
              if is_paged_entry(e))
    idxs = np.full((MB,), MB, np.int32)          # MB = OOB -> mode="drop"
    bids = np.zeros((MB,), np.int32)
    for i, (ti, bid) in enumerate(entries):
        idxs[i] = ti
        bids[i] = bid
    clear = np.full((MB,), NB, np.int32)         # NB = OOB -> mode="drop"
    clear[:len(clear_bids)] = np.asarray(list(clear_bids), np.int32)
    pool = kvsan.pool_if_active()
    if pool is not None:
        pool.on_set_row(slot, [int(bid) for _, bid in entries])
    return _arm_jit(cache, np.int32(slot), jnp.asarray(idxs),
                    jnp.asarray(bids), jnp.asarray(clear))


def release_slot(cache, slot: int):
    """Clear a retired slot's block-table row (every paged layer).

    The pool bytes themselves are reclaimed host-side by the block
    manager; clearing the table keeps the device state from ever reading
    freed blocks through a stale row."""
    pool = kvsan.pool_if_active()
    if pool is not None:
        pool.on_release_rows([slot])
    out = dict(cache)
    out["layers"] = [
        dict(e, bt=e["bt"].at[slot].set(-1)) if is_paged_entry(e) else e
        for e in cache["layers"]]
    return out


def _release_impl(cache, rows):
    out = dict(cache)
    out["layers"] = [
        dict(e, bt=e["bt"].at[rows].set(-1, mode="drop"))
        if is_paged_entry(e) else e
        for e in cache["layers"]]
    return out


_release_jit = jax.jit(_release_impl)


def release_slots(cache, slots):
    """Batched :func:`release_slot`: clear all the retired slots' table
    rows with ONE jitted dispatch (the continuous scheduler's
    batched-retire path — a reap of R slots used to issue R x n_layers
    eager scatter ops).  The row vector is padded to the slot count with
    an out-of-range index (dropped by the scatter) so every reap hits
    the same compiled program regardless of how many slots retire."""
    if not slots:
        return cache
    B = next(e["bt"].shape[0] for e in cache["layers"]
             if is_paged_entry(e))
    rows = np.full((B,), B, np.int32)        # B = OOB -> mode="drop"
    rows[:len(slots)] = np.asarray(list(slots), np.int32)
    pool = kvsan.pool_if_active()
    if pool is not None:
        pool.on_release_rows([int(s) for s in slots])
    return _release_jit(cache, jnp.asarray(rows))


# ------------------------------------------------------------------- CoW
def copy_blocks(cache, pairs):
    """Device-side block copies ``[(src, dst), ...]`` across every paged
    layer — the data half of copy-on-write (the table/refcount half lives
    in the block manager).  Copies K/V *and* pos."""
    if not pairs:
        return cache
    pool = kvsan.pool_if_active()
    if pool is not None:
        pool.on_copy([(int(s), int(d)) for s, d in pairs])
    src = jnp.asarray([p[0] for p in pairs], jnp.int32)
    dst = jnp.asarray([p[1] for p in pairs], jnp.int32)
    out = dict(cache)
    new_layers = []
    for entry in cache["layers"]:
        if not is_paged_entry(entry):
            new_layers.append(entry)
            continue
        e = dict(entry)
        for key in _POOL_KEYS:
            if key in entry:
                e[key] = entry[key].at[dst].set(entry[key][src])
        new_layers.append(e)
    out["layers"] = new_layers
    return out


def set_block_table_row(cache, slot: int, block_ids):
    """Point ``slot``'s table row at ``block_ids`` (pad with -1)."""
    pool = kvsan.pool_if_active()
    if pool is not None:
        pool.on_set_row(slot, [int(b) for b in block_ids])
    out = dict(cache)
    new_layers = []
    for entry in cache["layers"]:
        if not is_paged_entry(entry):
            new_layers.append(entry)
            continue
        MB = entry["bt"].shape[1]
        table = np.full((MB,), -1, np.int32)
        table[:len(block_ids)] = np.asarray(block_ids, np.int32)
        new_layers.append(dict(entry,
                               bt=entry["bt"].at[slot].set(
                                   jnp.asarray(table))))
    out["layers"] = new_layers
    return out


def slice_prefill_rows(cache, rows):
    """P-row view of a paged cache for a fused chunk forward.

    Pool leaves (K/V/pos) pass through by reference — the view's block
    tables index the same shared pool, so chunk scatters land in place
    and shared-prefix blocks are readable at zero copy cost.  Per-row
    leaves (``bt``, ``length``, and any non-paged layer's recurrent
    state) are gathered at ``rows`` ([P] int32, pre-clipped in range)."""
    layers = []
    for entry in cache["layers"]:
        if is_paged_entry(entry):
            layers.append({k: (v[rows] if k == "bt" else v)
                           for k, v in entry.items()})
        else:
            layers.append(jax.tree.map(lambda x: x[rows], entry))
    return {"layers": layers, "length": cache["length"][rows]}


def merge_prefill_rows(cache, sub, slots):
    """Fold a chunk forward's updated P-row view back into the full
    cache.  Pool leaves replace wholesale (the forward already scattered
    into them through the sliced tables); per-row leaves scatter to
    ``slots`` — out-of-range entries drop, so padding lanes (``slots``
    set past the batch) write nowhere."""
    kvsan.emit_merge_check(cache, slots)
    layers = []
    for entry, s in zip(cache["layers"], sub["layers"]):
        if is_paged_entry(entry):
            layers.append({k: (entry[k].at[slots].set(s[k], mode="drop")
                               if k == "bt" else s[k])
                           for k in entry})
        else:
            layers.append(jax.tree.map(
                lambda x, y: x.at[slots].set(y, mode="drop"), entry, s))
    return {"layers": layers,
            "length": cache["length"].at[slots].set(sub["length"],
                                                    mode="drop")}


# ------------------------------------------------------------- accounting
def paged_block_bytes(cache) -> int:
    """Bytes one pool block occupies summed over all paged layers."""
    total = 0
    for entry in cache["layers"]:
        if not is_paged_entry(entry):
            continue
        for key in _POOL_KEYS:
            if key in entry:
                leaf = entry[key]
                total += int(np.prod(leaf.shape[1:])) * leaf.dtype.itemsize
    return total


def ring_cache_bytes(cache) -> int:
    """Total allocated bytes of a ring cache's K/V/pos leaves (the paged
    comparison baseline: the ring allocates its full footprint upfront)."""
    total = 0
    for entry in cache["layers"]:
        for key in _POOL_KEYS:
            if isinstance(entry, dict) and key in entry:
                leaf = entry[key]
                total += leaf.size * leaf.dtype.itemsize
    return total
