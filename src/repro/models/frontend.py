"""Modality frontend *stubs* (assignment carve-out).

The audio (EnCodec conv codec) and vision (Pixtral ViT) encoders are NOT
implemented — ``input_specs()`` in the launcher provides precomputed frame /
patch embeddings of the right shape, and these helpers generate matching
random stand-ins for tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def vlm_patch_embeds(cfg: ModelConfig, key, batch, n_patches=None,
                     dtype=jnp.float32):
    """Stand-in for Pixtral-ViT + projector output: [B, P, d_model]."""
    n = n_patches or cfg.n_patches
    return jax.random.normal(key, (batch, n, cfg.d_model), dtype) * 0.02


def audio_frame_tokens(cfg: ModelConfig, key, batch, n_frames,
                       dtype=jnp.int32):
    """Stand-in for EnCodec tokenization: [B, T, K] codebook ids."""
    return jax.random.randint(key, (batch, n_frames, cfg.n_codebooks), 0,
                              cfg.vocab_size, dtype)


def conditioning_prefix(cfg: ModelConfig, key, batch, n_cond=16,
                        dtype=jnp.float32):
    """MusicGen text-conditioning prefix embeddings (stub): [B, n, d]."""
    return jax.random.normal(key, (batch, n_cond, cfg.d_model), dtype) * 0.02
