"""Pluggable attention backends for the decode hot path.

Every cached attention in the model funnels through one of two per-layer
decode calls, built once by :mod:`repro.models.attention` and dispatched
here:

* ``tree_decode``  — stage-only PPD guess pass: T tree tokens attend to
  the ring cache plus each other through the [T,T] tree mask;
* ``cache_decode`` — committed decode (vanilla single-token step) and
  prefill: tokens already scattered into the cache attend over it.

Backends:

* ``"ref"``    — the pure-jnp oracle path (`layers.chunked_attend`): it
  concatenates cache and tree K/V along the sequence axis and builds the
  full [B,T,S+T] visibility mask.  Correct everywhere (training, prefill,
  sharded serving) and the equivalence baseline for everything else.
* ``"pallas"`` — routes the decode hot path through
  :func:`repro.kernels.ops.tree_decode_attention`: the flash tree kernel
  streams the ring cache HBM->VMEM in blocks with an online-softmax
  accumulator, folding the tree tail in as the final grid step.  No cache
  concat, no [B,T,S+T] mask, no staged copy of the cache — the per-step
  HBM traffic is the cache read itself, which is the bandwidth floor.
  Prefill (whole-prompt and chunked, T > 1) streams through the same
  kernel; only extra-masked commits fall back to the ref math.

Selection is per-call — a string (or backend instance) threaded from the
engine / CLI through ``forward`` — never an import-time global, so one
process can run and compare both backends (the tests sweep them).

``capture_calls`` is a test hook recording, per dispatched call, which
backend ran and the shapes it materialized; the acceptance tests use it to
prove the pallas path never builds a concatenated [S+T] K/V or mask.
"""
from __future__ import annotations

import contextlib

import jax.numpy as jnp

from repro.kernels.ops import tree_decode_attention

from .paged_cache import gather_view

from .layers import chunked_attend

_REGISTRY: dict = {}
_TRACE = None


def register_backend(name):
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls()
        return cls
    return deco


def available_backends():
    return tuple(sorted(_REGISTRY))


def get_backend(backend=None) -> "AttentionBackend":
    """Resolve a backend name (None -> "ref") or pass an instance through."""
    if backend is None:
        backend = "ref"
    if isinstance(backend, AttentionBackend):
        return backend
    try:
        return _REGISTRY[backend]
    except KeyError:
        raise ValueError(f"unknown attention backend {backend!r}; "
                         f"available: {available_backends()}") from None


@contextlib.contextmanager
def capture_calls():
    """Record one event dict per dispatched backend call (at trace time).

    Events carry ``backend``, ``op``, and the shapes the call materialized
    (``kv_len`` / ``mask`` for ref's concatenated buffers, the raw cache
    length for pallas).  Use a freshly-jitted step inside the context —
    already-compiled functions skip tracing and record nothing.
    """
    global _TRACE
    prev, _TRACE = _TRACE, []
    try:
        yield _TRACE
    finally:
        _TRACE = prev


def _record(**event):
    if _TRACE is not None:
        _TRACE.append(event)


def _norm_tree_mask(tree_mask, q_pos, window):
    """Normalize the tree mask to [B,T,T] bool, folding in the causal
    (+window) constraint among the T new tokens — the kernel applies ONLY
    this mask to the tree tail, whereas the ref path's ``build_mask`` also
    position-checks it, so the positional constraints must live in the
    mask for the backends to agree (a window smaller than the tree's
    positional span is the case that bites).  ``tree_mask=None`` means
    plain causal self attention (vanilla step)."""
    tm = q_pos[:, None, :] <= q_pos[:, :, None]
    if window:
        tm &= q_pos[:, None, :] > (q_pos[:, :, None] - window)
    if tree_mask is not None:
        if tree_mask.ndim == 2:
            tree_mask = tree_mask[None]
        tm = tm & tree_mask
    return tm


class AttentionBackend:
    """Decode-attention strategy.  All tensors arrive pre-projected:
    q [B,T,H,D]; cache K/V [B,S,Hkv,D(v)] with per-slot positions
    kv_pos [B,S] (-1 invalid); tree/self K/V [B,T,Hkv,D(v)]; q_pos [B,T].
    The optional ``q2``/``k2_*`` pair is a second score stream summed into
    the logits (MLA-absorb latents); ``scale`` is then mandatory.

    With ``bt`` (a [B, MB] block table) the cache operands are *paged
    pools* instead — K/V [NB, bs, Hkv, D(v)], kv_pos [NB, bs] — and the
    backend reads them through the table (see
    :mod:`repro.models.paged_cache`): ref gathers block rows up front,
    pallas block-indexes the loads inside the kernel's S-loop."""

    name = "?"

    def tree_decode(self, q, k_cache, v_cache, kv_pos, k_tree, v_tree,
                    q_pos, tree_mask, *, window=0, scale=None, softcap=0.0,
                    q_chunk=0, q2=None, k2_cache=None, k2_tree=None,
                    bt=None):
        raise NotImplementedError

    def cache_decode(self, q, k_cache, v_cache, kv_pos, q_pos, k_self,
                     v_self, *, window=0, scale=None, softcap=0.0,
                     q_chunk=0, extra_mask=None, q2=None, k2_cache=None,
                     k2_self=None, bt=None):
        raise NotImplementedError


@register_backend("ref")
class RefBackend(AttentionBackend):
    """Oracle path: sequence-concat cache+tree K/V, full visibility mask,
    :func:`repro.models.layers.chunked_attend`.  Bit-identical to the
    pre-backend model code."""

    def tree_decode(self, q, k_cache, v_cache, kv_pos, k_tree, v_tree,
                    q_pos, tree_mask, *, window=0, scale=None, softcap=0.0,
                    q_chunk=0, q2=None, k2_cache=None, k2_tree=None,
                    bt=None):
        if bt is not None:
            (k_cache, v_cache, k2_cache), kv_pos = gather_view(
                bt, kv_pos, (k_cache, v_cache, k2_cache))
        if q2 is not None:
            q = jnp.concatenate([q, q2], axis=-1)
            k_cache = jnp.concatenate([k_cache, k2_cache], axis=-1)
            k_tree = jnp.concatenate([k_tree, k2_tree], axis=-1)
        B, T = q.shape[:2]
        S = k_cache.shape[1]
        k_all = jnp.concatenate([k_cache, k_tree], axis=1)
        v_all = jnp.concatenate([v_cache, v_tree], axis=1)
        kv_pos_all = jnp.concatenate([kv_pos, q_pos], axis=1)
        kv_valid = jnp.concatenate([kv_pos >= 0, jnp.ones((B, T), bool)], 1)
        tm = _norm_tree_mask(tree_mask, q_pos, window)
        em = jnp.concatenate([jnp.ones((B, T, S), bool), tm], axis=2)
        _record(backend=self.name, op="tree_decode", paged=bt is not None,
                kv_len=k_all.shape[1], mask=tuple(em.shape))
        return chunked_attend(q, k_all, v_all, q_positions=q_pos,
                              kv_positions=kv_pos_all, kv_valid=kv_valid,
                              window=window, extra_mask=em, scale=scale,
                              softcap=softcap, q_chunk=q_chunk)

    def cache_decode(self, q, k_cache, v_cache, kv_pos, q_pos, k_self,
                     v_self, *, window=0, scale=None, softcap=0.0,
                     q_chunk=0, extra_mask=None, q2=None, k2_cache=None,
                     k2_self=None, bt=None):
        if bt is not None:
            (k_cache, v_cache, k2_cache), kv_pos = gather_view(
                bt, kv_pos, (k_cache, v_cache, k2_cache))
        if q2 is not None:
            q = jnp.concatenate([q, q2], axis=-1)
            k_cache = jnp.concatenate([k_cache, k2_cache], axis=-1)
        _record(backend=self.name, op="cache_decode", paged=bt is not None,
                kv_len=k_cache.shape[1],
                mask=(q.shape[0], q.shape[1], k_cache.shape[1]))
        return chunked_attend(q, k_cache, v_cache, q_positions=q_pos,
                              kv_positions=kv_pos, kv_valid=kv_pos >= 0,
                              window=window, extra_mask=extra_mask,
                              scale=scale, softcap=softcap, q_chunk=q_chunk)


@register_backend("pallas")
class PallasBackend(AttentionBackend):
    """Flash tree-decode kernel path (interpret mode off-TPU).

    ``tree_decode`` maps 1:1 onto the kernel.  ``cache_decode`` covers
    committed attention at any T: K/V are already scattered into the
    cache, so each query finds itself (and, causally, the rest of its
    chunk) there via the kernel's per-query ``kv_pos <= q_pos`` mask,
    while the call's own K/V ride along as a fully-masked tree tail (a
    bit-exact no-op of the online softmax).  T == 1 is the vanilla decode
    step; T > 1 is prefill — whole-prompt or chunked — streamed through
    the same kernel with no [B,T,S] mask materialized.  Extra-masked
    commits (arbitrary visibility edits) defer to the ref math.
    """

    def tree_decode(self, q, k_cache, v_cache, kv_pos, k_tree, v_tree,
                    q_pos, tree_mask, *, window=0, scale=None, softcap=0.0,
                    q_chunk=0, q2=None, k2_cache=None, k2_tree=None,
                    bt=None):
        del q_chunk                      # the kernel streams over S instead
        tm = _norm_tree_mask(tree_mask, q_pos, window)
        if bt is not None:
            # per-sequence positions are gathered (a [B, S] int view —
            # cheap); K/V stay in the pool and the kernel's S-loop loads
            # each block via the prefetched table.
            _, kv_pos = gather_view(bt, kv_pos, ())
        _record(backend=self.name, op="tree_decode", paged=bt is not None,
                cache_len=k_cache.shape[1], tree_len=k_tree.shape[1],
                mask=tuple(tm.shape))
        return tree_decode_attention(q, k_cache, v_cache, kv_pos, k_tree,
                                     v_tree, q_pos, tm, window=window,
                                     scale=scale, softcap=softcap, q2=q2,
                                     k2_cache=k2_cache, k2_tree=k2_tree,
                                     block_tables=bt)

    def cache_decode(self, q, k_cache, v_cache, kv_pos, q_pos, k_self,
                     v_self, *, window=0, scale=None, softcap=0.0,
                     q_chunk=0, extra_mask=None, q2=None, k2_cache=None,
                     k2_self=None, bt=None):
        B, T = q.shape[:2]
        if extra_mask is not None:
            # masked commit (arbitrary visibility): not expressible as
            # cache-causal + tree tail — defer to the ref math.
            return get_backend("ref").cache_decode(
                q, k_cache, v_cache, kv_pos, q_pos, k_self, v_self,
                window=window, scale=scale, softcap=softcap,
                q_chunk=q_chunk, extra_mask=extra_mask, q2=q2,
                k2_cache=k2_cache, k2_self=k2_self, bt=bt)
        # committed decode/prefill: the tokens are already in the cache
        # (scattered before this call), so mask the tail off entirely and
        # let the kernel's per-query causal cache mask do the work.
        tm = jnp.zeros((B, T, T), bool)
        if bt is not None:
            _, kv_pos = gather_view(bt, kv_pos, ())
        _record(backend=self.name, op="cache_decode", paged=bt is not None,
                cache_len=k_cache.shape[1], mask=tuple(tm.shape))
        return tree_decode_attention(q, k_cache, v_cache, kv_pos, k_self,
                                     v_self, q_pos, tm, window=window,
                                     scale=scale, softcap=softcap, q2=q2,
                                     k2_cache=k2_cache, k2_tree=k2_self,
                                     block_tables=bt)
