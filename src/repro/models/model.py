"""Unified decoder model covering every assigned architecture.

All functions are pure; ``cfg`` is a hashable frozen dataclass meant to be
closed over / passed statically to ``jax.jit``.

Three passes share one implementation:

* ``forward(...)``                      — training / teacher logits (no cache)
* ``forward(..., cache=..)``            — prefill: K/V written, states committed
* ``forward(..., cache=.., stage_only=True)``  — PPD guess pass: tree/chain
  tokens read the cache but nothing is committed; staged K/V are returned.
* ``forward(..., cache=.., commit_mask=..)``   — PPD commit pass for
  recurrent mixers (dt-masked re-scan) + masked K/V scatter for attention.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .config import (ATTN, MLA, RGLRU, SSM, LayerSpec, ModelConfig,
                     layer_specs, scan_plan)
from .layers import embed_init, init_mlp, mlp, rms_norm


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ------------------------------------------------------------------ params
def init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 3)
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype),
         "ln2": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.use_post_norms:
        p["ln1_post"] = jnp.zeros((cfg.d_model,), dtype)
        p["ln2_post"] = jnp.zeros((cfg.d_model,), dtype)
    if spec.mixer == ATTN:
        p["attn"] = attn_mod.init_attention(ks[0], cfg, dtype)
    elif spec.mixer == MLA:
        p["attn"] = attn_mod.init_mla(ks[0], cfg, dtype)
    elif spec.mixer == SSM:
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg, dtype)
    elif spec.mixer == RGLRU:
        p["rglru"] = rglru_mod.init_rglru(ks[0], cfg, dtype)
    if spec.mixer != SSM:                      # mamba blocks have no FFN
        if spec.is_moe:
            p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    specs = layer_specs(cfg)
    keys = jax.random.split(key, cfg.n_layers + 3)
    layers = [init_layer(keys[i], cfg, specs[i], dtype)
              for i in range(cfg.n_layers)]
    p = {"final_norm": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.scan_layers:
        o, per, n_rep = scan_plan(cfg)
        p["layers_prefix"] = layers[:o]
        p["layers_scan"] = tuple(
            _stack_trees([layers[o + r * per + j] for r in range(n_rep)])
            for j in range(per))
        p["layers_tail"] = layers[o + per * n_rep:]
    else:
        p["layers"] = layers
    if cfg.modality == "audio":
        p["embed"] = jnp.stack([
            embed_init(k, cfg.vocab_size, cfg.d_model, dtype)
            for k in jax.random.split(keys[-1], cfg.n_codebooks)])
        p["codebook_heads"] = jnp.stack([
            embed_init(k, cfg.vocab_size, cfg.d_model, dtype).T
            for k in jax.random.split(keys[-2], cfg.n_codebooks)])
    else:
        p["embed"] = embed_init(keys[-1], cfg.vocab_size, cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            p["lm_head"] = embed_init(keys[-2], cfg.vocab_size,
                                      cfg.d_model, dtype).T
    if cfg.mtp_depth:
        k1, k2 = jax.random.split(keys[-3])
        p["mtp"] = {
            "norm_h": jnp.zeros((cfg.d_model,), dtype),
            "norm_e": jnp.zeros((cfg.d_model,), dtype),
            "proj": embed_init(k1, 2 * cfg.d_model, cfg.d_model, dtype),
            "layer": init_layer(k2, cfg, specs[-1], dtype),
        }
    return p


# ------------------------------------------------------------------ embed / unembed
def embed_tokens(params, cfg: ModelConfig, tokens):
    if cfg.modality == "audio":
        # tokens: [B,T,K]; params["embed"]: [K,V,d] -> sum over codebooks
        x = sum(params["embed"][k][tokens[..., k]]
                for k in range(cfg.n_codebooks))
    else:
        x = params["embed"][tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def unembed(params, cfg: ModelConfig, h):
    if cfg.modality == "audio":
        return jnp.einsum("btd,kdv->btkv", h, params["codebook_heads"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ head


# ------------------------------------------------------------------ caches
def init_cache(cfg: ModelConfig, batch, capacity, dtype=jnp.float32, *,
               paged: bool = False, block_size: int = 16,
               num_blocks: int | None = None,
               sliding_full_span: bool = False):
    """Decode cache pytree.

    ``paged=True`` replaces each attention layer's per-row ring strip
    with a block pool + per-sequence block table (see
    :mod:`repro.models.paged_cache`); recurrent (SSM / RG-LRU) state is
    unaffected.  ``num_blocks`` sizes the shared pool (default: ring
    parity — ``batch * ceil(capacity / block_size)``).
    ``sliding_full_span`` (ring only) skips the ``min(capacity, window)``
    cap on sliding-window layers — used for prefill rows whose content is
    spliced into paged pools, where shared-block content must be the same
    whatever the owning sequence's prompt length."""
    from . import paged_cache as paged_mod
    if paged:
        if cfg.scan_layers:
            raise NotImplementedError(
                "paged KV caches are not supported for scan-stacked layer "
                "configs (cfg.scan_layers); use the ring cache")
        if num_blocks is None:
            num_blocks = batch * paged_mod.num_seq_blocks(capacity,
                                                          block_size)
    layers = []
    for spec in layer_specs(cfg):
        if spec.mixer == ATTN:
            if paged:
                layers.append(paged_mod.make_paged_attn_cache(
                    cfg, batch, capacity, block_size, num_blocks, dtype))
            else:
                layers.append(attn_mod.make_attn_cache(
                    cfg, spec, batch, capacity, dtype,
                    full_span=sliding_full_span))
        elif spec.mixer == MLA:
            if paged:
                layers.append(paged_mod.make_paged_mla_cache(
                    cfg, batch, capacity, block_size, num_blocks, dtype))
            else:
                layers.append(attn_mod.make_mla_cache(cfg, batch, capacity,
                                                      dtype))
        elif spec.mixer == SSM:
            layers.append(ssm_mod.make_ssm_cache(cfg, batch, dtype))
        elif spec.mixer == RGLRU:
            layers.append(rglru_mod.make_rglru_cache(cfg, batch, dtype))
    if cfg.scan_layers:
        o, per, n_rep = scan_plan(cfg)
        return {"prefix": layers[:o],
                "scan": tuple(
                    _stack_trees([layers[o + r * per + j]
                                  for r in range(n_rep)])
                    for j in range(per)),
                "tail": layers[o + per * n_rep:],
                "length": jnp.zeros((batch,), jnp.int32)}
    return {"layers": layers, "length": jnp.zeros((batch,), jnp.int32)}


def _map_cache(cfg: ModelConfig, fn, *caches):
    """Map ``fn(batch_axis, *leaves)`` over one or more decode caches of the
    same structure.  Scan-stacked leaves carry a leading repeat axis, so
    their batch axis is 1; everything else is batch-first."""
    if cfg.scan_layers:
        return {
            "prefix": jax.tree.map(lambda *ls: fn(0, *ls),
                                   *[c["prefix"] for c in caches]),
            "scan": jax.tree.map(lambda *ls: fn(1, *ls),
                                 *[c["scan"] for c in caches]),
            "tail": jax.tree.map(lambda *ls: fn(0, *ls),
                                 *[c["tail"] for c in caches]),
            "length": fn(0, *[c["length"] for c in caches]),
        }
    return {"layers": jax.tree.map(lambda *ls: fn(0, *ls),
                                   *[c["layers"] for c in caches]),
            "length": fn(0, *[c["length"] for c in caches])}


def write_cache_rows(cfg: ModelConfig, cache, rows, index):
    """Copy all batch rows of ``rows`` (a small batch-R cache, e.g. a
    freshly prefilled R=1 row) into ``cache`` starting at batch ``index``.

    This is the per-slot admission primitive of the continuous-batching
    scheduler: one request's prefilled K/V (or recurrent state) replaces a
    retired slot's row without reinitialising the whole pool cache.
    Paged caches splice rows through
    :func:`repro.models.paged_cache.write_prefill_blocks` instead — pool
    leaves have no batch axis to copy into."""
    from .paged_cache import is_paged_cache
    if is_paged_cache(cache):
        raise ValueError("write_cache_rows on a paged cache; use "
                         "paged_cache.write_prefill_blocks")

    def put(ax, dst, src):
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), index, axis=ax)
    return _map_cache(cfg, put, cache, rows)


def slice_cache_rows(cfg: ModelConfig, cache, index, n: int = 1):
    """Rows ``[index, index+n)`` of a (ring) cache as a batch-``n``
    cache — the read-side complement of :func:`write_cache_rows`
    (``index`` may be traced)."""
    def take(ax, leaf):
        return jax.lax.dynamic_slice_in_dim(leaf, index, n, axis=ax)
    return _map_cache(cfg, take, cache)


def _reset_rows_impl(cache, slot, start):
    from jax.tree_util import DictKey, tree_map_with_path

    body = {k: v for k, v in cache.items() if k != "length"}

    def f(path, leaf):
        last = path[-1]
        if isinstance(last, DictKey) and last.key == "pos":
            if leaf.ndim == 3:                     # scan-stacked [rep,B,C]
                return leaf.at[:, slot].set(-1)
            return leaf.at[slot].set(-1)
        return leaf

    out = tree_map_with_path(f, body)
    out["length"] = cache["length"].at[slot].set(start)
    return out


_reset_rows_jit = jax.jit(_reset_rows_impl)


def reset_cache_rows(cfg: ModelConfig, cache, slot, start: int = 0):
    """Invalidate one ring row in place: ``pos[slot] = -1`` on every
    attention entry and ``length[slot] = start``.

    This is the chunked-prefill ``prefill_begin`` primitive — a retired
    slot's ring row keeps stale positions (release is host-side only),
    and the ring scatter records positions with ``.max``, so a chunk
    written over a longer previous occupant would otherwise lose its
    position records to the stale ones.  One jitted dispatch, shape-
    stable in ``slot``/``start``.  Ring caches only (paged rows are
    re-armed through the block table instead)."""
    from .paged_cache import is_paged_cache
    if is_paged_cache(cache):
        raise ValueError("reset_cache_rows on a paged cache; arm blocks "
                         "via paged_cache.begin_prefill_row")
    del cfg
    return _reset_rows_jit(cache, jnp.int32(slot), jnp.int32(start))


def trim_cache(cfg: ModelConfig, cache, lengths):
    """Invalidate cached tokens at positions >= ``lengths`` (per row) and
    set per-row ``length``.

    Ring entries die via ``pos = -1``; the stale K/V bytes stay but are
    never attended.  Recurrent-state (SSM / RG-LRU) caches hold no
    positions and cannot be trimmed — chain architectures must prefill at
    exact prompt length instead of a padded bucket."""
    from jax.tree_util import DictKey, tree_map_with_path

    from .paged_cache import is_paged_cache
    if is_paged_cache(cache):
        # pool "pos" leaves are block-indexed, not row-indexed; trimming
        # a paged sequence means freeing its tail blocks (block manager).
        raise ValueError("trim_cache on a paged cache; free tail blocks "
                         "via the serving block manager instead")

    body = {k: v for k, v in cache.items() if k != "length"}

    def f(path, leaf):
        last = path[-1]
        if isinstance(last, DictKey) and last.key == "pos":
            ax = 1 if leaf.ndim == 3 else 0        # scan-stacked [rep,B,C]
            L = lengths.reshape((1,) * ax + (-1, 1))
            return jnp.where(leaf < L, leaf, -1)
        return leaf

    out = tree_map_with_path(f, body)
    out["length"] = jnp.asarray(lengths, jnp.int32)
    return out


# ------------------------------------------------------------------ blocks
def _apply_layer(lp, cfg, spec, x, positions, cache_entry, *, extra_mask,
                 q_chunk, stage_only, commit_mask, moe_exact=False,
                 attn_backend=None):
    staged = None
    h = rms_norm(x, lp["ln1"], cfg.rms_eps, plus_one=True)
    if spec.mixer in (ATTN, MLA):
        fn = attn_mod.attn_apply if spec.mixer == ATTN else attn_mod.mla_apply
        if commit_mask is not None and cache_entry is not None:
            # commit pass: recompute projections, masked scatter
            out, _, staged = fn(lp["attn"], cfg, spec, h, positions,
                                cache_entry, extra_mask=extra_mask,
                                q_chunk=q_chunk, stage_only=True,
                                backend=attn_backend)
            scat = (attn_mod.scatter_kv if spec.mixer == ATTN
                    else attn_mod.scatter_mla)
            cache_entry = scat(cache_entry, *staged, positions, commit_mask)
        else:
            out, cache_entry, staged = fn(lp["attn"], cfg, spec, h, positions,
                                          cache_entry, extra_mask=extra_mask,
                                          q_chunk=q_chunk,
                                          stage_only=stage_only,
                                          backend=attn_backend)
    elif spec.mixer == SSM:
        out, cache_entry = ssm_mod.ssm_apply(
            lp["ssm"], cfg, h, cache_entry, dt_mask=commit_mask,
            update_cache=(cache_entry is not None) and not stage_only)
    elif spec.mixer == RGLRU:
        out, cache_entry = rglru_mod.rglru_apply(
            lp["rglru"], cfg, h, cache_entry, dt_mask=commit_mask,
            update_cache=(cache_entry is not None) and not stage_only)
    if cfg.use_post_norms:
        out = rms_norm(out, lp["ln1_post"], cfg.rms_eps, plus_one=True)
    x = x + out

    aux = 0.0
    if spec.mixer != SSM:
        h = rms_norm(x, lp["ln2"], cfg.rms_eps, plus_one=True)
        if spec.is_moe:
            out, aux = moe_mod.moe_apply(lp["moe"], cfg, h, exact=moe_exact)
        else:
            out = mlp(lp["mlp"], h, cfg.act)
        if cfg.use_post_norms:
            out = rms_norm(out, lp["ln2_post"], cfg.rms_eps, plus_one=True)
        x = x + out
    return x, cache_entry, staged, aux


def forward(params, cfg: ModelConfig, tokens=None, positions=None, *,
            embeds=None, prefix_embeds=None, cache=None, extra_mask=None,
            q_chunk: int = 0, stage_only: bool = False,
            commit_mask=None, return_hidden: bool = False,
            remat: bool = False, moe_exact: bool = False,
            skip_unembed: bool = False, attn_backend=None):
    """Returns (logits, new_cache, staged_list, aux_loss).

    tokens: [B,T] int (audio: [B,T,K]); embeds: [B,T,d] (alternative input);
    prefix_embeds: [B,P,d] prepended (VLM patch prefix); positions [B,T_total].
    attn_backend selects the decode attention backend ("ref" / "pallas",
    see :mod:`repro.models.backend`); cached attention layers only.
    """
    if embeds is None:
        x = embed_tokens(params, cfg, tokens)
    else:
        x = embeds
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32),
                                     (B, T))

    specs = layer_specs(cfg)
    aux0 = jnp.zeros((), jnp.float32)

    def layer_fn(lp, spec, x, centry):
        return _apply_layer(lp, cfg, spec, x, positions, centry,
                            extra_mask=extra_mask, q_chunk=q_chunk,
                            stage_only=stage_only, commit_mask=commit_mask,
                            moe_exact=moe_exact, attn_backend=attn_backend)

    if cfg.scan_layers:
        o, per, n_rep = scan_plan(cfg)
        new_cache_struct = {"prefix": [], "scan": None, "tail": []}
        staged_struct = {"prefix": [], "scan": None, "tail": []}
        aux_total = aux0

        def eager(part, idx_range, x):
            nonlocal aux_total
            for slot, i in enumerate(idx_range):
                centry = cache[part][slot] if cache is not None else None
                x, centry, staged, aux = layer_fn(params[f"layers_{part}"][slot],
                                                  specs[i], x, centry)
                new_cache_struct[part].append(centry)
                staged_struct[part].append(staged)
                aux_total = aux_total + aux
            return x

        x = eager("prefix", range(o), x)

        block_specs = tuple(specs[o + j] for j in range(per))

        def body(carry, xs):
            xb, aux = carry
            p_slices, c_slices = xs
            new_c, new_s = [], []
            for j in range(per):
                xb, ce, st, a = layer_fn(p_slices[j], block_specs[j], xb,
                                         c_slices[j])
                new_c.append(ce)
                new_s.append(st)
                aux = aux + a
            return (xb, aux), (tuple(new_c), tuple(new_s))

        if per:
            body_fn = jax.checkpoint(body) if remat else body
            c_scan = (cache["scan"] if cache is not None
                      else tuple(None for _ in range(per)))
            (x, aux_total), (nc, ns) = jax.lax.scan(
                body_fn, (x, aux_total), (params["layers_scan"], c_scan))
            new_cache_struct["scan"] = nc
            staged_struct["scan"] = ns

        x = eager("tail", range(o + per * n_rep, cfg.n_layers), x)
        staged_list = staged_struct
    else:
        staged_list, new_layers = [], []
        aux_total = aux0
        for i, spec in enumerate(specs):
            centry = cache["layers"][i] if cache is not None else None
            fn = (jax.checkpoint(layer_fn, static_argnums=(1,))
                  if remat else layer_fn)
            x, centry, staged, aux = fn(params["layers"][i], spec, x, centry)
            new_layers.append(centry)
            staged_list.append(staged)
            aux_total = aux_total + aux

    hidden_pre_final = x
    if skip_unembed:
        # caller gathers the rows it needs, then applies final_norm +
        # unembed itself (avoids materializing [B,T,V] logits — the
        # dominant memory term for large-vocab training shapes).
        logits = None
    else:
        x = rms_norm(x, params["final_norm"], cfg.rms_eps, plus_one=True)
        logits = unembed(params, cfg, x)

    new_cache = None
    if cache is not None:
        length = cache["length"]
        if not stage_only:
            if commit_mask is not None:
                length = length + commit_mask.astype(jnp.int32).sum(axis=1)
            else:
                length = length + T
        if cfg.scan_layers:
            new_cache = dict(new_cache_struct, length=length)
        else:
            new_cache = {"layers": new_layers, "length": length}
    if return_hidden:
        return logits, new_cache, staged_list, aux_total, hidden_pre_final
    return logits, new_cache, staged_list, aux_total


def mtp_logits(params, cfg: ModelConfig, hidden, tokens_next, positions):
    """DeepSeek-V3 multi-token-prediction head (depth 1).

    hidden: [B,T,d] pre-final-norm states; tokens_next: [B,T] (inputs shifted
    by one).  Returns logits predicting t+2.
    """
    mp = params["mtp"]
    h = rms_norm(hidden, mp["norm_h"], cfg.rms_eps, plus_one=True)
    e = embed_tokens(params, cfg, tokens_next)
    e = rms_norm(e, mp["norm_e"], cfg.rms_eps, plus_one=True)
    x = jnp.concatenate([h, e], axis=-1) @ mp["proj"]
    spec = layer_specs(cfg)[-1]
    x, _, _, _ = _apply_layer(mp["layer"], cfg, spec, x, positions, None,
                              extra_mask=None, q_chunk=0, stage_only=False,
                              commit_mask=None)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps, plus_one=True)
    return unembed(params, cfg, x)
