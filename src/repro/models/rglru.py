"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)
a_t = exp(-c * softplus(Lambda) * sigma(W_a u_t)),  i_t = sigma(W_x u_t)

Prefill uses an associative scan (the recurrence is linear); decode/chain
processes T tokens the same way from a cached initial state.  A commit
mask turns rejected chain tokens into identities (a=1, input=0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init

_C = 8.0
_NB = 16          # block-diagonal gate blocks (Griffin's BlockDiagonalLinear)


def _block_diag_init(key, w, dtype):
    bs = w // _NB
    return (jax.random.normal(key, (_NB, bs, bs)) * bs ** -0.5).astype(dtype)


def _block_diag(x, wgt, b):
    B, S, w = x.shape
    xb = x.reshape(B, S, _NB, w // _NB)
    y = jnp.einsum("bsni,nij->bsnj", xb, wgt).reshape(B, S, w)
    return y + b


def init_rglru(key, cfg: ModelConfig, dtype=jnp.float32):
    r = cfg.rglru
    w, d = r.lru_width, cfg.d_model
    ks = jax.random.split(key, 7)
    # Lambda init so that a in [0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(ks[6], (w,), minval=0.9 ** 2, maxval=0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * _C)))   # softplus^-1
    return {
        "w_x": dense_init(ks[0], d, w, dtype),
        "w_y": dense_init(ks[1], d, w, dtype),
        "conv_w": (jax.random.normal(ks[2], (w, r.conv_width)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "gate_a_w": _block_diag_init(ks[3], w, dtype),
        "gate_a_b": jnp.zeros((w,), dtype),
        "gate_x_w": _block_diag_init(ks[4], w, dtype),
        "gate_x_b": jnp.zeros((w,), dtype),
        "lambda": lam.astype(jnp.float32),
        "w_out": dense_init(ks[5], w, d, dtype),
    }


def make_rglru_cache(cfg: ModelConfig, batch, dtype=jnp.float32):
    r = cfg.rglru
    return {
        "conv_in": jnp.zeros((batch, r.conv_width - 1, r.lru_width), dtype),
        "h": jnp.zeros((batch, r.lru_width), jnp.float32),
    }


def _causal_conv(x, w, b, conv_in):
    width = w.shape[1]
    xp = jnp.concatenate([conv_in.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[:, i] for i in range(width))
    return out + b


def rglru_apply(params, cfg: ModelConfig, x, cache=None, *, dt_mask=None,
                update_cache=True):
    """x: [B,S,d] -> (y [B,S,d], new_cache)."""
    r = cfg.rglru
    B, S, _ = x.shape
    gate = jax.nn.gelu(x @ params["w_y"], approximate=True)

    u = x @ params["w_x"]
    conv_in = (cache["conv_in"] if cache is not None
               else jnp.zeros((B, r.conv_width - 1, r.lru_width), x.dtype))
    u = _causal_conv(u, params["conv_w"], params["conv_b"], conv_in)

    rt = jax.nn.sigmoid(_block_diag(u, params["gate_a_w"],
                                    params["gate_a_b"]).astype(jnp.float32))
    it = jax.nn.sigmoid(_block_diag(u, params["gate_x_w"],
                                    params["gate_x_b"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lambda"]) * rt     # [B,S,w]
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2 * log_a), 1e-12)) \
        * it * u.astype(jnp.float32)

    if dt_mask is not None:
        m = dt_mask.astype(jnp.float32)[..., None]
        a = a * m + (1.0 - m)            # masked -> a=1
        gated_in = gated_in * m          # masked -> no input

    h0 = (cache["h"] if cache is not None
          else jnp.zeros((B, r.lru_width), jnp.float32))

    # h_t = a_t h_{t-1} + b_t  via associative scan on (a, b)
    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    aa, bb = jax.lax.associative_scan(combine, (a, gated_in), axis=1)
    h = aa * h0[:, None, :] + bb                             # [B,S,w]
    final_h = h[:, -1, :]

    y = (h.astype(x.dtype) * gate) @ params["w_out"]

    new_cache = cache
    if update_cache:
        if dt_mask is not None:
            n_acc = dt_mask.astype(jnp.int32).sum(axis=1)
            hist_u = jnp.concatenate(
                [conv_in.astype(x.dtype), x @ params["w_x"]], axis=1)

            def take(hst, n):
                return jax.lax.dynamic_slice_in_dim(hst, n, r.conv_width - 1, 0)
            conv_new = jax.vmap(take)(hist_u, n_acc)
        else:
            hist_u = jnp.concatenate(
                [conv_in.astype(x.dtype), x @ params["w_x"]], axis=1)
            conv_new = hist_u[:, -(r.conv_width - 1):]
        new_cache = {"conv_in": conv_new, "h": final_h}
    return y, new_cache
