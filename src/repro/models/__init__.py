from .backend import available_backends, capture_calls, get_backend
from .config import (ATTN, FULL, MLA, RGLRU, SLIDING, SSM, LayerSpec,
                     MLAConfig, ModelConfig, MoEConfig, RGLRUConfig,
                     SSMConfig, layer_specs, param_count)
from .model import (embed_tokens, forward, init_cache, init_params,
                    mtp_logits, trim_cache, unembed, write_cache_rows)
from .paged_cache import (copy_blocks, is_paged_cache, num_seq_blocks,
                          paged_block_bytes, release_slot, release_slots,
                          ring_cache_bytes, set_block_table_row,
                          write_prefill_blocks)
