from .backend import available_backends, capture_calls, get_backend
from .config import (ATTN, FULL, MLA, RGLRU, SLIDING, SSM, LayerSpec,
                     MLAConfig, ModelConfig, MoEConfig, RGLRUConfig,
                     SSMConfig, layer_specs, param_count)
from .model import (embed_tokens, forward, init_cache, init_params,
                    mtp_logits, reset_cache_rows, slice_cache_rows,
                    trim_cache, unembed, write_cache_rows)
from .paged_cache import (begin_prefill_row, copy_blocks, is_paged_cache,
                          merge_prefill_rows, num_seq_blocks,
                          paged_block_bytes, release_slot, release_slots,
                          ring_cache_bytes, set_block_table_row,
                          slice_prefill_rows, write_prefill_blocks,
                          write_prefill_chunk)
