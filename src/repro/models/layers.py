"""Basic neural-net layers shared across architectures (pure-functional JAX)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# Optional decode-attention sharding pin (set by the launcher): a
# PartitionSpec-axes tuple for [B, S, H, D] attention operands.  GSPMD
# otherwise re-tiles the KV cache over the idle model axis and pays
# per-layer K/V all-gathers (21.5 MiB x 2 x n_layers for gemma3-1b @32k)
# — cheaper to keep batch-sharded decode attention device-local.
_ATTN_BATCH_AXIS = None


def set_attention_sharding(batch_axis):
    global _ATTN_BATCH_AXIS
    _ATTN_BATCH_AXIS = batch_axis


def _pin_batch_local(*arrays):
    if _ATTN_BATCH_AXIS is None:
        return arrays
    from jax.sharding import PartitionSpec as P
    out = []
    for a in arrays:
        spec = P(_ATTN_BATCH_AXIS, *([None] * (a.ndim - 1)))
        out.append(jax.lax.with_sharding_constraint(a, spec))
    return out


# ---------------------------------------------------------------- init utils
def dense_init(key, d_in, d_out, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab, d, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------- norms
def rms_norm(x, weight, eps=1e-6, plus_one=False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:                           # gemma-style (1 + w) scaling
        w = 1.0 + w
    return (x * w).astype(dt)


# ---------------------------------------------------------------- rope
def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta=10_000.0):
    """x: [..., T, H, D] (or [..., T, D]); positions broadcastable to [..., T]."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # [D/2]
    ang = positions.astype(jnp.float32)[..., None] * inv        # [..., T, D/2]
    # broadcast over a possible head axis
    while ang.ndim < x.ndim:
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- mlp
def init_mlp(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(params, x, act="silu"):
    g = x @ params["w_gate"]
    u = x @ params["w_up"]
    if act == "gelu":
        g = jax.nn.gelu(g, approximate=True)
    else:
        g = jax.nn.silu(g)
    return (g * u) @ params["w_down"]


# ---------------------------------------------------------------- attention core
def masked_attend(q, k, v, mask, scale, softcap=0.0):
    """q: [B,Tq,H,D]  k/v: [B,Tk,Hkv,D]  mask: [B,Tq,Tk] bool (True=visible).

    GQA: H must be a multiple of Hkv.  Returns [B,Tq,H,D].
    """
    B, Tq, H, D = q.shape
    Hkv = k.shape[2]
    Dv = v.shape[-1]
    g = H // Hkv
    qg = q.reshape(B, Tq, Hkv, g, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    # guard fully-masked rows (padding queries)
    any_visible = jnp.any(mask, axis=-1)[:, None, None, :, None]
    probs = jnp.where(any_visible, probs, 0.0)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Tq, Hkv * g, Dv).astype(q.dtype)


def build_mask(q_positions, kv_positions, kv_valid, window=0, extra_mask=None):
    """Causal(+window) visibility mask.

    q_positions: [B,Tq] int; kv_positions: [B,Tk] int; kv_valid: [B,Tk] bool.
    extra_mask: optional [B,Tq,Tk] (or [Tq,Tk]) bool, ANDed in (tree / EPT masks).
    """
    causal = kv_positions[:, None, :] <= q_positions[:, :, None]
    m = causal & kv_valid[:, None, :]
    if window:
        m &= kv_positions[:, None, :] > (q_positions[:, :, None] - window)
    if extra_mask is not None:
        if extra_mask.ndim == 2:
            extra_mask = extra_mask[None]
        m &= extra_mask
    return m


def chunked_attend(q, k, v, *, q_positions, kv_positions, kv_valid,
                   window=0, extra_mask=None, scale=None, softcap=0.0,
                   q_chunk=0):
    """Query-chunked attention: bounds the [Tq,Tk] score working set.

    With ``q_chunk == 0`` (or Tq <= q_chunk) falls back to a single block.
    """
    B, Tq, H, D = q.shape
    scale = scale if scale is not None else D ** -0.5
    if not q_chunk or Tq <= q_chunk:
        q, k, v = _pin_batch_local(q, k, v)
        mask = build_mask(q_positions, kv_positions, kv_valid, window,
                          extra_mask)
        out = masked_attend(q, k, v, mask, scale, softcap)
        return _pin_batch_local(out)[0]

    n, rem = divmod(Tq, q_chunk)

    def block(s, width):
        qc = jax.lax.dynamic_slice_in_dim(q, s, width, axis=1)
        pc = jax.lax.dynamic_slice_in_dim(q_positions, s, width, axis=1)
        em = None
        if extra_mask is not None:
            em3 = extra_mask if extra_mask.ndim == 3 else extra_mask[None]
            em = jnp.broadcast_to(em3, (B,) + em3.shape[1:])
            em = jax.lax.dynamic_slice_in_dim(em, s, width, axis=1)
        mask = build_mask(pc, kv_positions, kv_valid, window, em)
        return masked_attend(qc, k, v, mask, scale, softcap)

    outs = jax.lax.map(lambda i: block(i * q_chunk, q_chunk), jnp.arange(n))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, n * q_chunk, H, v.shape[-1])
    if rem:                               # trailing partial chunk (e.g. the
        tail = block(n * q_chunk, rem)    # prompt-token rows in distillation)
        out = jnp.concatenate([out, tail], axis=1)
    return out
