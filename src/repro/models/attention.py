"""Attention mixers: GQA (full / sliding-window) and MLA (DeepSeek/MiniCPM3).

Both expose the same call contract used by :mod:`repro.models.model`:

    out, cache_entry = apply(params, cfg, spec, x, positions, cache_entry,
                             extra_mask=..., q_chunk=..., backend=...)

``cache_entry`` is a per-layer dict pytree; new K/V are *staged* into it at
``positions % C`` immediately (prefill) or returned for deferred commit
(tree decode — see ``stage_only``).

Decode paths (a live cache) build (q, cache view, new K/V, masks) once and
dispatch to the selected attention backend (:mod:`repro.models.backend`):
``"ref"`` runs the concat-and-mask oracle, ``"pallas"`` streams the ring
cache through the flash tree-decode kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .backend import get_backend
from .config import ModelConfig, LayerSpec, SLIDING
from .layers import apply_rope, rms_norm, dense_init, chunked_attend
from .paged_cache import is_paged_entry, scatter_paged


# ------------------------------------------------------------------ GQA
def init_attention(key, cfg: ModelConfig, dtype=jnp.float32):
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * Dh, dtype),
        "wk": dense_init(ks[1], d, Hkv * Dh, dtype),
        "wv": dense_init(ks[2], d, Hkv * Dh, dtype),
        "wo": dense_init(ks[3], H * Dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), dtype)
        p["k_norm"] = jnp.ones((Dh,), dtype)
    return p


def make_attn_cache(cfg: ModelConfig, spec: LayerSpec, batch, capacity,
                    dtype=jnp.float32, full_span: bool = False):
    """``full_span`` keeps sliding-window layers at the full capacity
    instead of the ``min(capacity, window)`` ring cap — required for
    prefill rows that feed paged-pool splices, where block content must
    not depend on how much of the prompt outlived this row's ring."""
    if spec.span == SLIDING and not full_span:
        capacity = min(capacity, spec.window)
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, capacity, Hkv, Dh), dtype),
        "v": jnp.zeros((batch, capacity, Hkv, Dh), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
    }


def _theta(cfg: ModelConfig, spec: LayerSpec) -> float:
    if spec.span == SLIDING and cfg.rope_local_theta is not None:
        return cfg.rope_local_theta
    return cfg.rope_theta


def _project_qkv(p, cfg, spec, x, positions):
    B, T, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, T, H, Dh)
    k = (x @ p["wk"]).reshape(B, T, Hkv, Dh)
    v = (x @ p["wv"]).reshape(B, T, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    th = _theta(cfg, spec)
    q = apply_rope(q, positions, th)
    k = apply_rope(k, positions, th)
    return q, k, v


def scatter_kv(cache, k_new, v_new, positions, accept_mask=None):
    """Write staged K/V into the ring cache at ``positions % C``.

    ``accept_mask`` ([B,T] bool) drops rejected tree tokens (OOB-slot trick).
    Paged entries scatter through the block table instead (position ``p``
    lands at ``(bt[b, p // bs], p % bs)``; no ring wrap).
    """
    if is_paged_entry(cache):
        return scatter_paged(cache, {"k": k_new, "v": v_new}, positions,
                             accept_mask)
    C = cache["k"].shape[1]
    slots = positions % C
    if accept_mask is not None:
        slots = jnp.where(accept_mask, slots, C)      # C is out of range -> drop
        positions = jnp.where(accept_mask, positions, -1)
    bidx = jnp.arange(k_new.shape[0])[:, None]
    out = dict(cache)
    out["k"] = cache["k"].at[bidx, slots].set(k_new, mode="drop")
    out["v"] = cache["v"].at[bidx, slots].set(v_new, mode="drop")
    out["pos"] = cache["pos"].at[bidx, slots].max(positions, mode="drop")
    # max keeps the newer (larger) position on ring wrap *and* ignores -1s.
    return out


def attn_apply(params, cfg: ModelConfig, spec: LayerSpec, x, positions,
               cache=None, *, extra_mask=None, q_chunk=0, stage_only=False,
               backend=None):
    """x: [B,T,d]; positions: [B,T].

    Without a cache: self-attention over the T tokens (training / scratch
    prefill).  With a cache: attend over cache ∪ current tokens; if
    ``stage_only`` the K/V are NOT written (tree decode — commit happens
    after verification via :func:`scatter_kv`), otherwise they are written
    in place (prefill).  ``backend`` selects the decode attention backend
    (None -> "ref"); it only affects cached paths.
    """
    B, T, _ = x.shape
    q, k, v = _project_qkv(params, cfg, spec, x, positions)
    window = spec.window if spec.span == SLIDING else 0
    staged = (k, v)
    scale = cfg.head_dim ** -0.5

    if cache is None:
        out = chunked_attend(q, k, v, q_positions=positions,
                             kv_positions=positions,
                             kv_valid=jnp.ones((B, T), bool),
                             window=window, extra_mask=extra_mask,
                             scale=scale, softcap=cfg.logit_softcap,
                             q_chunk=q_chunk)
    elif stage_only:
        out = get_backend(backend).tree_decode(
            q, cache["k"], cache["v"], cache["pos"], k, v, positions,
            extra_mask, window=window, scale=scale,
            softcap=cfg.logit_softcap, q_chunk=q_chunk,
            bt=cache.get("bt"))
    else:
        cache = scatter_kv(cache, k, v, positions)
        out = get_backend(backend).cache_decode(
            q, cache["k"], cache["v"], cache["pos"], positions, k, v,
            window=window, scale=scale, softcap=cfg.logit_softcap,
            q_chunk=q_chunk, extra_mask=extra_mask, bt=cache.get("bt"))
    out = out.reshape(B, T, cfg.n_heads * cfg.head_dim) @ params["wo"]
    return out, cache, staged


# ------------------------------------------------------------------ MLA
def init_mla(key, cfg: ModelConfig, dtype=jnp.float32):
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 5)
    return {
        "w_dq": dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "w_uq": dense_init(ks[1], m.q_lora_rank,
                           H * (m.qk_nope_dim + m.qk_rope_dim), dtype),
        "w_dkv": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_dim, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "w_ukv": dense_init(ks[3], m.kv_lora_rank,
                            H * (m.qk_nope_dim + m.v_head_dim), dtype),
        "wo": dense_init(ks[4], H * m.v_head_dim, d, dtype),
    }


def make_mla_cache(cfg: ModelConfig, batch, capacity, dtype=jnp.float32):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, capacity, m.qk_rope_dim), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
    }


def scatter_mla(cache, ckv, krope, positions, accept_mask=None):
    if is_paged_entry(cache):
        return scatter_paged(cache, {"ckv": ckv, "krope": krope},
                             positions, accept_mask)
    C = cache["ckv"].shape[1]
    slots = positions % C
    if accept_mask is not None:
        slots = jnp.where(accept_mask, slots, C)
        positions = jnp.where(accept_mask, positions, -1)
    bidx = jnp.arange(ckv.shape[0])[:, None]
    out = dict(cache)
    out["ckv"] = cache["ckv"].at[bidx, slots].set(ckv, mode="drop")
    out["krope"] = cache["krope"].at[bidx, slots].set(krope, mode="drop")
    out["pos"] = cache["pos"].at[bidx, slots].max(positions, mode="drop")
    return out


def _mla_qkv(params, cfg, x, positions):
    m, H = cfg.mla, cfg.n_heads
    B, T, _ = x.shape
    cq = rms_norm(x @ params["w_dq"], params["q_norm"], cfg.rms_eps)
    q = (cq @ params["w_uq"]).reshape(B, T, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    dkv = x @ params["w_dkv"]
    ckv = rms_norm(dkv[..., :m.kv_lora_rank], params["kv_norm"], cfg.rms_eps)
    krope = apply_rope(dkv[..., m.kv_lora_rank:], positions, cfg.rope_theta)
    return q_nope, q_rope, ckv, krope


def _mla_decompress(cfg, w_ukv, ckv, krope):
    """Latent streams [B,S,R]/[B,S,Dr] -> per-head K/V [B,S,H,D(v)]
    (the naive, paper-faithful MLA path)."""
    m, H = cfg.mla, cfg.n_heads
    B, S = ckv.shape[:2]
    kv = jnp.einsum("bsr,rhd->bshd", ckv, w_ukv)
    k_nope, v = kv[..., :m.qk_nope_dim], kv[..., m.qk_nope_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                  (B, S, H, m.qk_rope_dim))], axis=-1)
    return k, v


def _mla_attend(params, cfg, q_nope, q_rope, ckv, krope, q_positions,
                kv_pos, kv_valid, extra_mask, q_chunk):
    """Attention given latent K/V streams. Two math-equivalent paths."""
    m, H = cfg.mla, cfg.n_heads
    B, T = q_nope.shape[:2]
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    w_ukv = params["w_ukv"].reshape(m.kv_lora_rank, H,
                                    m.qk_nope_dim + m.v_head_dim)
    if cfg.mla.absorb:
        # Fold W_UK into q; attend in latent space (MQA with D=rank+rope).
        w_uk = w_ukv[..., :m.qk_nope_dim]                     # [R,H,Dn]
        q_lat = jnp.einsum("bthd,rhd->bthr", q_nope, w_uk)
        q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)     # [B,T,H,R+Dr]
        k_cat = jnp.concatenate([ckv, krope], axis=-1)[:, :, None, :]
        v_lat = ckv[:, :, None, :]
        o_lat = chunked_attend(q_cat, k_cat, v_lat, q_positions=q_positions,
                               kv_positions=kv_pos, kv_valid=kv_valid,
                               extra_mask=extra_mask, scale=scale,
                               q_chunk=q_chunk)               # [B,T,H,R]
        w_uv = w_ukv[..., m.qk_nope_dim:]                     # [R,H,Dv]
        out = jnp.einsum("bthr,rhd->bthd", o_lat, w_uv)
    else:
        # Naive: decompress latents to per-head K/V (paper-faithful port).
        k, v = _mla_decompress(cfg, w_ukv, ckv, krope)
        q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attend(q_cat, k, v, q_positions=q_positions,
                             kv_positions=kv_pos, kv_valid=kv_valid,
                             extra_mask=extra_mask, scale=scale,
                             q_chunk=q_chunk)
    return out.reshape(B, T, H * m.v_head_dim) @ params["wo"]


def mla_apply(params, cfg: ModelConfig, spec: LayerSpec, x, positions,
              cache=None, *, extra_mask=None, q_chunk=0, stage_only=False,
              backend=None):
    B, T, _ = x.shape
    m, H = cfg.mla, cfg.n_heads
    q_nope, q_rope, ckv, krope = _mla_qkv(params, cfg, x, positions)
    staged = (ckv, krope)
    if cache is None:
        out = _mla_attend(params, cfg, q_nope, q_rope, ckv, krope,
                          positions, positions, jnp.ones((B, T), bool),
                          extra_mask, q_chunk)
        return out, cache, staged

    if not stage_only:
        cache = scatter_mla(cache, ckv, krope, positions)
    be = get_backend(backend)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    w_ukv = params["w_ukv"].reshape(m.kv_lora_rank, H,
                                    m.qk_nope_dim + m.v_head_dim)
    lat = lambda a: a[:, :, None, :]     # latent stream -> MQA head axis
    if m.absorb:
        # Fold W_UK into q; attend in latent space as MQA with the cache's
        # ckv / krope streams read in place (two score streams — no
        # feature-concatenated cache copy on the kernel path).
        w_uk = w_ukv[..., :m.qk_nope_dim]                     # [R,H,Dn]
        q_lat = jnp.einsum("bthd,rhd->bthr", q_nope, w_uk)
        if stage_only:
            o_lat = be.tree_decode(
                q_lat, lat(cache["ckv"]), lat(cache["ckv"]), cache["pos"],
                lat(ckv), lat(ckv), positions, extra_mask, scale=scale,
                q_chunk=q_chunk, q2=q_rope, k2_cache=lat(cache["krope"]),
                k2_tree=lat(krope), bt=cache.get("bt"))
        else:
            o_lat = be.cache_decode(
                q_lat, lat(cache["ckv"]), lat(cache["ckv"]), cache["pos"],
                positions, lat(ckv), lat(ckv), scale=scale,
                q_chunk=q_chunk, extra_mask=extra_mask, q2=q_rope,
                k2_cache=lat(cache["krope"]), k2_self=lat(krope),
                bt=cache.get("bt"))
        out = jnp.einsum("bthr,rhd->bthd", o_lat,
                         w_ukv[..., m.qk_nope_dim:])          # [B,T,H,Dv]
    else:
        # Naive: decompress latents to per-head K/V — cache and new tokens
        # separately, so the kernel path never concatenates them.
        k_c, v_c = _mla_decompress(cfg, w_ukv, cache["ckv"],
                                   cache["krope"])
        k_t, v_t = _mla_decompress(cfg, w_ukv, ckv, krope)
        q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
        if stage_only:
            out = be.tree_decode(q_cat, k_c, v_c, cache["pos"], k_t, v_t,
                                 positions, extra_mask, scale=scale,
                                 q_chunk=q_chunk, bt=cache.get("bt"))
        else:
            out = be.cache_decode(q_cat, k_c, v_c, cache["pos"], positions,
                                  k_t, v_t, scale=scale, q_chunk=q_chunk,
                                  extra_mask=extra_mask,
                                  bt=cache.get("bt"))
    out = out.reshape(B, T, H * m.v_head_dim) @ params["wo"]
    return out, cache, staged
