"""PPD x speculative decoding (paper §5.3).

PPD is orthogonal to classic draft-model speculative decoding: the paper
applies PPD to the *draft* (Vicuna-68M) and uses it to speculate for the
*target* (Vicuna-7B), gaining up to 1.22x over spec-decode alone.  This
example reproduces the composition at CPU scale:

  * target  = demo decoder (6L/320d)
  * draft   = same-family 2L/128d model, distilled from nothing (random
    proxy here; the benchmark uses trained models)
  * spec-decode with a vanilla draft   vs   spec-decode with a PPD draft

The composition's win: the PPD draft produces its gamma proposals in
fewer draft forward passes, so the draft-side latency drops while the
target-side acceptance stays the same.

Run:  PYTHONPATH=src python examples/ppd_plus_spec_decode.py
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.demo import CONFIG as TARGET_CFG
from repro.core import init_prompt_params
from repro.data.pipeline import DataPipeline
from repro.models import init_params
from repro.serving import EngineConfig, LLMEngine, SamplingParams
from repro.training.train_loop import pretrain_base, train_prompt_tokens

DRAFT_CFG = TARGET_CFG.replace(name="ppd-demo-draft", n_layers=3,
                               d_model=160, n_heads=4, n_kv_heads=4,
                               head_dim=40, d_ff=384)
M, GAMMA = 3, 4


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=120,
                    help="quick co-training so draft/target agree")
    ap.add_argument("--n-new", type=int, default=64)
    args = ap.parse_args()

    pipe = DataPipeline(TARGET_CFG.vocab_size, 160, 8, seed=0)
    print("== training target + draft on the same synthetic language ==")
    tparams = init_params(TARGET_CFG, jax.random.PRNGKey(0))
    tparams = pretrain_base(tparams, TARGET_CFG, pipe,
                            steps=args.train_steps, lr=3e-3, verbose=False)
    dparams = init_params(DRAFT_CFG, jax.random.PRNGKey(1))
    dparams = pretrain_base(dparams, DRAFT_CFG, pipe,
                            steps=args.train_steps, lr=3e-3, verbose=False)
    print("== distilling prompt tokens into the DRAFT (paper §5.3) ==")
    ppd = init_prompt_params(DRAFT_CFG, jax.random.PRNGKey(2), m=M,
                             base_embed=dparams["embed"])
    ppd, _ = train_prompt_tokens(dparams, ppd, DRAFT_CFG, pipe, steps=100,
                                 m=M, lr=3e-2, verbose=False)

    prompt = pipe.val_prompts(1, 32)[0]
    config = EngineConfig(decode="ppd+spec", scheduler="static", m=M,
                          gamma=GAMMA, capacity=512, batch_size=1)
    sampling = SamplingParams(max_tokens=args.n_new)

    print("== spec-decode: vanilla draft ==")
    sd = LLMEngine(config, params=tparams, cfg=TARGET_CFG,
                   draft_params=dparams, draft_cfg=DRAFT_CFG)
    t0 = time.time()
    out_v = sd.generate([prompt], sampling)[0].token_ids
    t_v = time.time() - t0
    st_v = sd.strategy.stats
    print(f"  {st_v.tokens} tokens | target steps {st_v.target_steps} "
          f"(accept-len {st_v.accept_len:.2f}) | draft steps "
          f"{st_v.draft_steps} | {t_v:.1f}s")

    print("== spec-decode: PPD-accelerated draft ==")
    sp = LLMEngine(config, params=tparams, cfg=TARGET_CFG,
                   draft_params=dparams, draft_cfg=DRAFT_CFG,
                   draft_ppd=ppd)
    t0 = time.time()
    out_p = sp.generate([prompt], sampling)[0].token_ids
    t_p = time.time() - t0
    st_p = sp.strategy.stats
    print(f"  {st_p.tokens} tokens | target steps {st_p.target_steps} "
          f"(accept-len {st_p.accept_len:.2f}) | draft steps "
          f"{st_p.draft_steps} | {t_p:.1f}s")

    same = np.array_equal(out_v, out_p)
    print(f"outputs identical: {same} "
          "(both equal the target's greedy output by construction)")
    saved = 1 - st_p.draft_steps / max(st_v.draft_steps, 1)
    print(f"draft forward passes saved by PPD: {saved:.0%} "
          f"-> combined speedup {t_v / t_p:.2f}x over vanilla-draft "
          "spec-decode")


if __name__ == "__main__":
    main()
