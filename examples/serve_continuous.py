"""Continuous-batching PPD serving demo.

Replays the ISSUE acceptance workload — 12 requests with mixed
``max_new_tokens`` in {16, 64, 256} over 4 decode slots — through the
static and continuous engines and shows:

* identical outputs, token for token (temperature 0), and
* measurably fewer model forward passes for the continuous scheduler
  (static batching pads every batch to its slowest request).

Run:  PYTHONPATH=src python examples/serve_continuous.py [--fast]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.demo import SMOKE as CFG
from repro.core import init_prompt_params
from repro.data.pipeline import DataPipeline
from repro.models import init_params
from repro.serving import (ContinuousPPDEngine, ContinuousVanillaEngine,
                           PPDEngine, Request, VanillaEngine)

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true",
                help="shrink token budgets for a quick smoke run")
ap.add_argument("--slots", type=int, default=4)
args = ap.parse_args()

LENS = ([8, 24, 48] if args.fast else [16, 64, 256]) * 4   # 12 requests
PROMPT_LEN = 16
CAP = PROMPT_LEN + max(LENS) + 16

params = init_params(CFG, jax.random.PRNGKey(0))
ppd = init_prompt_params(CFG, jax.random.PRNGKey(1), m=3,
                         base_embed=params["embed"])
pipe = DataPipeline(CFG.vocab_size, PROMPT_LEN, 4, seed=0)
prompts = pipe.val_prompts(len(LENS), PROMPT_LEN)

engines = {
    "static PPD": PPDEngine(params, ppd, CFG, m=3, batch_size=args.slots,
                            capacity=CAP),
    "continuous PPD": ContinuousPPDEngine(params, ppd, CFG, m=3,
                                          batch_size=args.slots,
                                          capacity=CAP),
    "static vanilla": VanillaEngine(params, CFG, batch_size=args.slots,
                                    capacity=CAP),
    "continuous vanilla": ContinuousVanillaEngine(
        params, CFG, batch_size=args.slots, capacity=CAP),
}

outputs, fwd, walls = {}, {}, {}
for name, eng in engines.items():
    for i, L in enumerate(LENS):
        eng.add_request(Request(uid=i, prompt=prompts[i],
                                max_new_tokens=L))
    t0 = time.time()
    res = {r.uid: r for r in eng.run()}
    walls[name] = time.time() - t0
    outputs[name] = res
    fwd[name] = eng.total_forward_passes
    total = sum(len(r.tokens) for r in res.values())
    print(f"{name:>20}: {len(res)} requests, {total} tokens, "
          f"{eng.total_forward_passes} forward passes, "
          f"{walls[name]:.1f}s")
    if hasattr(eng, "metrics"):
        m = eng.metrics(list(res.values()))
        print(f"{'':>20}  goodput {m['goodput_tok_s']:.1f} tok/s, "
              f"mean TTFT {m['mean_ttft_s'] * 1e3:.0f} ms, "
              f"mean TPOT {m['mean_tpot_s'] * 1e3:.1f} ms, "
              f"idle slot-steps {m['idle_slot_steps']}")

for uid in outputs["static PPD"]:
    a = outputs["static PPD"][uid].tokens
    for name in ("continuous PPD", "static vanilla", "continuous vanilla"):
        np.testing.assert_array_equal(a, outputs[name][uid].tokens,
                                      f"{name} diverged on request {uid}")
print("\nall four engines agree token-for-token on every request")
for kind in ("PPD", "vanilla"):
    s, c = fwd[f"static {kind}"], fwd[f"continuous {kind}"]
    print(f"{kind}: continuous batching saves "
          f"{s - c} forward passes ({s} -> {c}, "
          f"{100.0 * (s - c) / s:.0f}% fewer)")
assert fwd["continuous vanilla"] < fwd["static vanilla"]
assert fwd["continuous PPD"] < fwd["static PPD"]
