"""Continuous-batching PPD serving demo, on the unified LLMEngine API.

Replays the mixed-length workload — 12 requests with ``max_tokens`` in
{16, 64, 256} over 4 decode slots — through all four decode x scheduler
combinations of one ``EngineConfig`` and shows:

* identical outputs, token for token (temperature 0), and
* measurably fewer model forward passes for the continuous scheduler
  (static batching pads every batch to its slowest request).

Run:  PYTHONPATH=src python examples/serve_continuous.py [--fast]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.demo import SMOKE as CFG
from repro.core import init_prompt_params
from repro.data.pipeline import DataPipeline
from repro.models import init_params
from repro.serving import EngineConfig, LLMEngine, SamplingParams

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true",
                help="shrink token budgets for a quick smoke run")
ap.add_argument("--slots", type=int, default=4)
args = ap.parse_args()

LENS = ([8, 24, 48] if args.fast else [16, 64, 256]) * 4   # 12 requests
PROMPT_LEN = 16
CAP = PROMPT_LEN + max(LENS) + 16

params = init_params(CFG, jax.random.PRNGKey(0))
ppd = init_prompt_params(CFG, jax.random.PRNGKey(1), m=3,
                         base_embed=params["embed"])
pipe = DataPipeline(CFG.vocab_size, PROMPT_LEN, 4, seed=0)
prompts = pipe.val_prompts(len(LENS), PROMPT_LEN)
sampling = [SamplingParams(max_tokens=L) for L in LENS]

outputs, fwd, walls = {}, {}, {}
for decode in ("ppd", "vanilla"):
    for sched in ("static", "continuous"):
        name = f"{sched} {decode}"
        llm = LLMEngine(EngineConfig(decode=decode, scheduler=sched,
                                     capacity=CAP,
                                     batch_size=args.slots),
                        params=params, cfg=CFG, ppd_params=ppd)
        t0 = time.time()
        outs = llm.generate(list(prompts), sampling)
        walls[name] = time.time() - t0
        outputs[name] = {o.request_id: o.token_ids for o in outs}
        fwd[name] = llm.total_forward_passes
        total = sum(len(t) for t in outputs[name].values())
        print(f"{name:>20}: {len(outs)} requests, {total} tokens, "
              f"{fwd[name]} forward passes, {walls[name]:.1f}s")
        if sched == "continuous":
            m = llm.metrics([o.metrics for o in outs])
            print(f"{'':>20}  goodput {m['goodput_tok_s']:.1f} tok/s, "
                  f"mean TTFT {m['mean_ttft_s'] * 1e3:.0f} ms, "
                  f"mean TPOT {m['mean_tpot_s'] * 1e3:.1f} ms, "
                  f"idle slot-steps {m['idle_slot_steps']}")

for uid in outputs["static ppd"]:
    a = outputs["static ppd"][uid]
    for name in ("continuous ppd", "static vanilla", "continuous vanilla"):
        np.testing.assert_array_equal(a, outputs[name][uid],
                                      f"{name} diverged on request {uid}")
print("\nall four engine configs agree token-for-token on every request")
for kind in ("ppd", "vanilla"):
    s, c = fwd[f"static {kind}"], fwd[f"continuous {kind}"]
    print(f"{kind}: continuous batching saves "
          f"{s - c} forward passes ({s} -> {c}, "
          f"{100.0 * (s - c) / s:.0f}% fewer)")
assert fwd["continuous vanilla"] < fwd["static vanilla"]
assert fwd["continuous ppd"] < fwd["static ppd"]
