"""Run PPD decoding across ALL ten assigned architectures (reduced
same-family configs) — tree mode for attention archs, chain mode for the
recurrent ones — asserting the exact-output guarantee for each.

Run:  PYTHONPATH=src python examples/multiarch_smoke.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.core import (default_chain_spec, device_buffers, init_ppd_state,
                        init_prompt_params, is_chain_arch, mk_default_tree,
                        ppd_decode_step, vanilla_decode_step)
from repro.models import forward, init_cache, init_params

M, N_NEW = 3, 24

for name in ARCH_NAMES:
    cfg = get_smoke_config(name)
    params = init_params(cfg, jax.random.PRNGKey(0))
    ppd = init_prompt_params(cfg, jax.random.PRNGKey(1), m=M,
                             base_embed=params["embed"])
    chain = is_chain_arch(cfg)
    states = ([default_chain_spec(max(k, 1), M) for k in range(M + 1)]
              if chain else mk_default_tree(M))
    bufs = device_buffers(states, M)

    if cfg.modality == "audio":
        prompt = jax.random.randint(jax.random.PRNGKey(2),
                                    (1, 8, cfg.n_codebooks), 0,
                                    cfg.vocab_size)
    else:
        prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                                    cfg.vocab_size)

    # vanilla reference
    cache = init_cache(cfg, 1, 128)
    logits, cache, _, _ = forward(params, cfg, prompt, cache=cache,
                                  moe_exact=True)
    tok = jnp.argmax(logits[:, -1], -1)
    ref = [np.asarray(tok[0])]
    while len(ref) < N_NEW:
        cache, tok, _ = vanilla_decode_step(params, cfg, cache, tok,
                                            moe_exact=True)
        ref.append(np.asarray(tok[0]))

    # PPD
    cache = init_cache(cfg, 1, 128)
    logits, cache, _, _ = forward(params, cfg, prompt, cache=cache,
                                  moe_exact=True)
    first = jnp.argmax(logits[:, -1], -1)
    st = init_ppd_state(cfg, cache, first, M, kmax=bufs["_kmax"])
    out, steps = [np.asarray(first[0])], 0
    step = jax.jit(lambda s: ppd_decode_step(params, ppd, cfg, bufs, s,
                                             m=M, moe_exact=True))
    t0 = time.time()
    while len(out) < N_NEW and steps < N_NEW + 4:
        st, info = step(st)
        steps += 1
        for t in np.asarray(info["accepted_path_tokens"])[0][1:]:
            if np.all(t >= 0):
                out.append(t)
        out.append(np.asarray(st.root_token)[0])
    dt = time.time() - t0

    ok = all(np.array_equal(a, b) for a, b in zip(out[:N_NEW], ref))
    mode = "chain" if chain else "tree "
    print(f"{name:24s} [{mode}] steps {steps:3d} for {N_NEW} tokens "
          f"({dt:.1f}s)  exact-match: {ok}")
    assert ok, name
print("all architectures decode correctly under PPD")
