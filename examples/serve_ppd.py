"""Batched PPD serving with the unified LLMEngine API.

One ``EngineConfig`` per decode strategy — the static scheduler packs a
queue of requests into fixed-size batches, prefills once, then runs
guess-and-verify steps until every row finishes (the static-shape
serving pattern a TPU deployment uses).  Compares PPD against the
vanilla autoregressive strategy and (optionally) the Medusa-head
baseline, all through the same facade.

Run:  PYTHONPATH=src python examples/serve_ppd.py [--arch granite-3-2b]
"""
import argparse
import time

import jax
import numpy as np

from repro.core import init_prompt_params
from repro.data.pipeline import DataPipeline
from repro.models import init_params
from repro.serving import EngineConfig, LLMEngine, SamplingParams

M = 3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ppd-demo")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=40)
    ap.add_argument("--medusa", action="store_true",
                    help="also run the Medusa-head baseline engine")
    args = ap.parse_args()

    if args.arch == "ppd-demo":
        from repro.configs.demo import CONFIG as cfg
    else:
        from repro.configs import get_smoke_config
        cfg = get_smoke_config(args.arch)

    params = init_params(cfg, jax.random.PRNGKey(0))
    ppd = init_prompt_params(cfg, jax.random.PRNGKey(1), m=M,
                             base_embed=params["embed"])
    pipe = DataPipeline(cfg.vocab_size, 32, args.batch,
                        n_codebooks=(cfg.n_codebooks
                                     if cfg.modality == "audio" else 0))
    prompts = list(pipe.val_prompts(args.requests, 32))
    sampling = SamplingParams(max_tokens=args.max_new)
    cap = 32 + args.max_new + 96

    def build(decode, **weights):
        return LLMEngine(EngineConfig(decode=decode, scheduler="static",
                                      m=M, capacity=cap,
                                      batch_size=args.batch),
                         params=params, cfg=cfg, **weights)

    llm = build("ppd", ppd_params=ppd)
    t0 = time.time()
    res_p = llm.generate(prompts, sampling)
    tp = time.time() - t0
    tok_p = sum(len(o.token_ids) for o in res_p)
    steps = sum(o.metrics.steps for o in res_p)
    print(f"PPD     : {tok_p} tokens, {tp:.1f}s, {tok_p / tp:.1f} tok/s, "
          f"accept-len {tok_p / max(steps, 1):.2f}")

    van = build("vanilla")
    t0 = time.time()
    res_v = van.generate(prompts, sampling)
    tv = time.time() - t0
    tok_v = sum(len(o.token_ids) for o in res_v)
    print(f"vanilla : {tok_v} tokens, {tv:.1f}s, {tok_v / tv:.1f} tok/s  "
          f"-> PPD speedup {tv / tp:.2f}x")
    same = all(np.array_equal(a.token_ids, b.token_ids)
               for a, b in zip(res_p, res_v))
    print(f"outputs exactly match vanilla: {same}")

    if args.medusa and cfg.modality == "text":
        from repro.models.medusa import init_medusa
        heads = init_medusa(cfg, jax.random.PRNGKey(2), m=M)
        med = build("medusa", medusa_heads=heads)
        t0 = time.time()
        res_m = med.generate(prompts, sampling)
        tm = time.time() - t0
        tok_m = sum(len(o.token_ids) for o in res_m)
        print(f"medusa  : {tok_m} tokens, {tm:.1f}s, {tok_m / tm:.1f} tok/s "
              "(heads untrained — see benchmarks for trained comparison)")


if __name__ == "__main__":
    main()
