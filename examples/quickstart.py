"""Quickstart: PPD guess-and-verify decoding in ~60 lines.

Builds a small decoder, appends 3 trained-embedding prompt tokens, and runs
greedy PPD decoding — demonstrating the core guarantee: the output is
EXACTLY the vanilla autoregressive output, in fewer forward passes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.demo import SMOKE as CFG
from repro.core import (device_buffers, init_ppd_state, init_prompt_params,
                        mk_default_tree, ppd_decode_step,
                        vanilla_decode_step)
from repro.models import forward, init_cache, init_params

M = 3                       # prompt tokens (paper §5: 3 for all experiments)
N_NEW = 48

key = jax.random.PRNGKey(0)
params = init_params(CFG, key)
ppd = init_prompt_params(CFG, jax.random.PRNGKey(1), m=M,
                         base_embed=params["embed"])
bufs = device_buffers(mk_default_tree(M), M)

prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0,
                            CFG.vocab_size)

# ---------------------------------------------------------------- vanilla
cache = init_cache(CFG, 1, 256)
logits, cache, _, _ = forward(params, CFG, prompt, cache=cache)
tok = jnp.argmax(logits[:, -1], -1)
vanilla, steps_v = [int(tok[0])], 0
step_v = jax.jit(lambda c, t: vanilla_decode_step(params, CFG, c, t))
t0 = time.time()
while len(vanilla) < N_NEW:
    cache, tok, _ = step_v(cache, tok)
    steps_v += 1
    vanilla.append(int(tok[0]))
t_vanilla = time.time() - t0

# ---------------------------------------------------------------- PPD
cache = init_cache(CFG, 1, 256)
logits, cache, _, _ = forward(params, CFG, prompt, cache=cache)
first = jnp.argmax(logits[:, -1], -1)
st = init_ppd_state(CFG, cache, first, M, kmax=bufs["_kmax"])
ppd_out, steps_p = [int(first[0])], 0
step_p = jax.jit(lambda s: ppd_decode_step(params, ppd, CFG, bufs, s, m=M))
t0 = time.time()
while len(ppd_out) < N_NEW:
    st, info = step_p(st)
    steps_p += 1
    for t in np.asarray(info["accepted_path_tokens"])[0][1:]:
        if t >= 0:
            ppd_out.append(int(t))
    ppd_out.append(int(np.asarray(st.root_token)[0]))
t_ppd = time.time() - t0

vanilla, ppd_out = vanilla[:N_NEW], ppd_out[:N_NEW]
print(f"vanilla : {steps_v + 1} forward passes, {t_vanilla:.2f}s")
print(f"PPD     : {steps_p + 1} forward passes, {t_ppd:.2f}s "
      f"(accept-len {N_NEW / (steps_p + 1):.2f})")
print(f"outputs identical: {vanilla == ppd_out}")
assert vanilla == ppd_out, "PPD must reproduce the vanilla output exactly"
print("NOTE: prompt tokens here are UNTRAINED — see train_ppd_e2e.py for "
      "the full pipeline where acceptance length (and speedup) grows.")
