"""Quickstart: PPD guess-and-verify serving in ~40 lines.

Builds a small decoder, appends 3 trained-embedding prompt tokens, and
serves one batch of prompts through the unified ``LLMEngine`` facade
twice — decode="ppd" and decode="vanilla" — demonstrating the core
guarantee: the PPD output is EXACTLY the vanilla autoregressive output,
in fewer forward passes.  (See examples/quickstart_core.py-style usage
in docs/architecture.md for the low-level decode-step API.)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.configs.demo import SMOKE as CFG
from repro.core import init_prompt_params
from repro.models import init_params
from repro.serving import EngineConfig, LLMEngine, SamplingParams

M = 3                       # prompt tokens (paper §5: 3 for all experiments)
N_NEW = 48

params = init_params(CFG, jax.random.PRNGKey(0))
ppd = init_prompt_params(CFG, jax.random.PRNGKey(1), m=M,
                         base_embed=params["embed"])
prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(2), (16,), 0,
                                         CFG.vocab_size))]
sampling = SamplingParams(max_tokens=N_NEW)   # greedy, 48 tokens

outs, walls, fwd = {}, {}, {}
for decode in ("vanilla", "ppd"):
    llm = LLMEngine(EngineConfig(decode=decode, scheduler="static",
                                 capacity=256, batch_size=1),
                    params=params, cfg=CFG, ppd_params=ppd)
    t0 = time.time()
    outs[decode] = llm.generate(prompts, sampling)[0].token_ids.tolist()
    walls[decode] = time.time() - t0
    fwd[decode] = llm.total_forward_passes

print(f"vanilla : {fwd['vanilla']} forward passes, "
      f"{walls['vanilla']:.2f}s")
print(f"PPD     : {fwd['ppd']} forward passes, {walls['ppd']:.2f}s "
      f"(accept-len {N_NEW / fwd['ppd']:.2f})")
print(f"outputs identical: {outs['vanilla'] == outs['ppd']}")
assert outs["vanilla"] == outs["ppd"], \
    "PPD must reproduce the vanilla output exactly"
assert fwd["ppd"] < fwd["vanilla"]
print("NOTE: prompt tokens here are UNTRAINED — see train_ppd_e2e.py for "
      "the full pipeline where acceptance length (and speedup) grows.")
