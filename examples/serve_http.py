"""HTTP serving walkthrough: the OpenAI-compatible front end,
self-contained in one process.

Boots the asyncio server on an ephemeral port over the smoke demo
model (paged KV), then acts as its own client:

1. one non-streaming completion (the OpenAI JSON shape),
2. the same prompt streamed over SSE — the chunks concatenate to the
   exact non-streaming token ids (greedy decode),
3. a mid-stream hangup — the server aborts the request and the paged
   pool returns to zero used blocks,
4. a burst past ``max_queue_depth`` — the overflow gets HTTP 429 with
   ``Retry-After`` instead of silently queueing,
5. ``/metrics`` and a graceful shutdown.

Run:  PYTHONPATH=src python examples/serve_http.py
"""
import asyncio
import json

import jax

from repro.configs.demo import SMOKE as CFG
from repro.core import init_prompt_params
from repro.models import init_params
from repro.serving import EngineConfig, LLMEngine
from repro.serving.server import make_server

params = init_params(CFG, jax.random.PRNGKey(0))
ppd = init_prompt_params(CFG, jax.random.PRNGKey(1), m=3,
                         base_embed=params["embed"])
llm = LLMEngine(EngineConfig(decode="ppd", scheduler="continuous",
                             kv="paged", capacity=256, batch_size=3),
                params=params, cfg=CFG, ppd_params=ppd)


async def post(port, payload):
    """Minimal HTTP client; returns (status, headers, body bytes)."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps(payload).encode()
    writer.write(b"POST /v1/completions HTTP/1.1\r\n"
                 b"Content-Length: %d\r\nConnection: close\r\n\r\n"
                 % len(body) + body)
    await writer.drain()
    raw = await reader.read()
    writer.close()
    head, _, rest = raw.partition(b"\r\n\r\n")
    lines = head.decode().split("\r\n")
    headers = dict(ln.lower().split(": ", 1) for ln in lines[1:] if ": " in ln)
    return int(lines[0].split()[1]), headers, rest


async def main():
    server = make_server(llm, port=0, max_queue_depth=4)
    await server.start()
    print(f"serving on http://127.0.0.1:{server.port}\n")

    # 1. non-streaming
    status, _, body = await post(server.port,
                                 {"prompt": [1, 2, 3], "max_tokens": 6})
    out = json.loads(body)
    plain = out["choices"][0]["token_ids"]
    print(f"non-streaming: HTTP {status}, tokens {plain}, "
          f"usage {out['usage']}")

    # 2. streaming: SSE chunks concatenate to the same ids
    reader, writer = await asyncio.open_connection("127.0.0.1",
                                                   server.port)
    pb = json.dumps({"prompt": [1, 2, 3], "max_tokens": 6,
                     "stream": True}).encode()
    writer.write(b"POST /v1/completions HTTP/1.1\r\n"
                 b"Content-Length: %d\r\n\r\n" % len(pb) + pb)
    await writer.drain()
    await reader.readuntil(b"\r\n\r\n")
    streamed = []
    while True:
        line = (await reader.readline()).strip()
        if not line.startswith(b"data: "):
            continue
        data = line[len(b"data: "):]
        if data == b"[DONE]":
            break
        streamed += json.loads(data)["choices"][0]["token_ids"]
    writer.close()
    print(f"streaming:     SSE chunks -> {streamed}")
    assert streamed == plain, "SSE must replay the non-streaming tokens"

    # 3. hang up mid-stream: the server aborts and reclaims the blocks
    reader, writer = await asyncio.open_connection("127.0.0.1",
                                                   server.port)
    pb = json.dumps({"prompt": [4, 5, 6], "max_tokens": 64,
                     "stream": True}).encode()
    writer.write(b"POST /v1/completions HTTP/1.1\r\n"
                 b"Content-Length: %d\r\n\r\n" % len(pb) + pb)
    await writer.drain()
    await reader.readuntil(b"\r\n\r\n")
    while b"token_ids" not in await reader.readline():
        pass
    writer.transport.abort()                 # client vanishes
    while server.bridge.counters["aborted"] < 1:
        await asyncio.sleep(0.05)
    while server.bridge._depth:
        await asyncio.sleep(0.05)
    print(f"disconnect:    aborted={server.bridge.counters['aborted']}, "
          f"used_blocks={llm.engine.block_mgr.used_blocks}")

    # 4. burst past the admission bound: explicit 429s, not a queue
    results = await asyncio.gather(*[
        post(server.port, {"prompt": [7, 8], "max_tokens": 8})
        for _ in range(12)])
    codes = sorted(s for s, _, _ in results)
    retry = next(h.get("retry-after") for s, h, _ in results if s == 429)
    print(f"burst of 12:   status codes {codes} "
          f"(429s carry Retry-After: {retry}s)")

    status, _, body = await asyncio.get_event_loop().create_task(
        metrics(server.port))
    agg = json.loads(body)["aggregate"]
    print(f"/metrics:      p99 TTFT {agg['p99_ttft_s'] * 1e3:.0f} ms, "
          f"p99 TPOT {agg['p99_tpot_s'] * 1e3:.1f} ms, "
          f"max concurrency {agg['max_concurrency_observed']}")

    await server.stop()                      # drains, joins engine thread
    print("graceful shutdown complete")


async def metrics(port):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n")
    await writer.drain()
    raw = await reader.read()
    writer.close()
    head, _, rest = raw.partition(b"\r\n\r\n")
    return int(head.split(b"\r\n")[0].split()[1]), {}, rest


asyncio.run(main())
