"""End-to-end PPD pipeline (the paper's full recipe at CPU-runnable scale):

  1. pretrain a base decoder on the synthetic dialogue language (stands in
     for the published Vicuna checkpoint — offline environment);
  2. freeze it and distill 3 prompt-token embeddings against its own
     logits (paper §3.3: KD loss w/ per-distance decay, random insertion);
  3. calibrate per-distance accumulative accuracy on a validation split
     and build the DYNAMIC SPARSE TREE (paper §4, Props 4.1-4.4);
  4. measure acceptance length + walltime speedup vs vanilla decoding,
     and save the trained prompt tokens.

Run:  PYTHONPATH=src python examples/train_ppd_e2e.py [--fast]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs.demo import CONFIG
from repro.core import (best_split, device_buffers, init_ppd_state,
                        init_prompt_params, mk_default_tree, ppd_decode_step,
                        vanilla_decode_step)
from repro.data.pipeline import DataPipeline
from repro.models import forward, init_cache, init_params
from repro.training.train_loop import pretrain_base, train_prompt_tokens

M = 3


def measure_accuracy(params, ppd, cfg, pipe, m, n_prompts=16, plen=48,
                     steps=12, topk=10):
    """Accumulative accuracy acc[d][j] of the prompt-token guesses vs the
    model's own greedy continuation (the paper's Fig. 6 measurement)."""
    from repro.core import mk_default_tree, device_buffers
    bufs = device_buffers(mk_default_tree(m), m)
    prompts = pipe.val_prompts(n_prompts, plen)
    hits = np.zeros((m, topk))
    total = 0
    step = jax.jit(lambda s: ppd_decode_step(params, ppd, cfg, bufs, s,
                                             m=m))
    for i in range(n_prompts):
        cache = init_cache(cfg, 1, 512)
        logits, cache, _, _ = forward(params, cfg,
                                      jnp.asarray(prompts[i:i + 1]),
                                      cache=cache)
        tok = jnp.argmax(logits[:, -1], -1)
        st = init_ppd_state(cfg, cache, tok, m, kmax=bufs["_kmax"])
        # greedy reference continuation
        ref = []
        c2 = cache
        t2 = tok
        for _ in range(steps + m + 1):
            c2, t2, _ = vanilla_decode_step(params, cfg, c2, t2)
            ref.append(int(t2[0]))
        # walk PPD steps; compare guess top-k at each distance
        ptr = 0
        for _ in range(steps):
            st, info = step(st)
            top = np.asarray(st.guess_idx)[0]               # [m,kmax] ranked
            acc_path = np.asarray(info["accepted_path_tokens"])[0]
            n_adv = sum(1 for t in acc_path[1:] if t >= 0) + 1
            ptr += n_adv
            if ptr + m >= len(ref):
                break
            for d in range(m):
                truth = ref[ptr + d]
                for j in range(min(topk, top.shape[1])):
                    if truth in top[d, :j + 1]:
                        hits[d, j:] += 1
                        break
            total += 1
    return hits / max(total, 1)


def generate_ppd(params, ppd, cfg, bufs, prompt, n_new, m):
    cache = init_cache(cfg, 1, 512)
    logits, cache, _, _ = forward(params, cfg, prompt, cache=cache)
    first = jnp.argmax(logits[:, -1], -1)
    st = init_ppd_state(cfg, cache, first, m, kmax=bufs["_kmax"])
    out, steps = [int(first[0])], 0
    step = jax.jit(lambda s: ppd_decode_step(params, ppd, cfg, bufs, s, m=m))
    while len(out) < n_new:
        st, info = step(st)
        steps += 1
        for t in np.asarray(info["accepted_path_tokens"])[0][1:]:
            if t >= 0:
                out.append(int(t))
        out.append(int(np.asarray(st.root_token)[0]))
    return out[:n_new], steps + 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="shrink steps for a <5 min run")
    ap.add_argument("--base-steps", type=int, default=400)
    ap.add_argument("--ppd-steps", type=int, default=600)
    ap.add_argument("--ckpt", default="benchmarks/results/ppd_demo_ckpt")
    args = ap.parse_args()
    if args.fast:
        args.base_steps, args.ppd_steps = 120, 150

    cfg = CONFIG
    pipe = DataPipeline(cfg.vocab_size, seq_len=192, batch_size=8, seed=0)
    print(f"== 1. pretraining base model ({cfg.name}, "
          f"{args.base_steps} steps) ==")
    params = init_params(cfg, jax.random.PRNGKey(0))
    params = pretrain_base(params, cfg, pipe, steps=args.base_steps,
                           lr=3e-3)

    print(f"== 2. distilling {M} prompt tokens ({args.ppd_steps} steps, "
          "base frozen) ==")
    ppd = init_prompt_params(cfg, jax.random.PRNGKey(1), m=M,
                             base_embed=params["embed"])
    ppd, _ = train_prompt_tokens(params, ppd, cfg, pipe,
                                 steps=args.ppd_steps, m=M, lr=3e-2)

    print("== 3. calibrating accuracies + building the dynamic tree ==")
    acc = measure_accuracy(params, ppd, cfg, pipe, M)
    np.set_printoptions(precision=3, suppress=True)
    print("accumulative accuracy acc[d][j] (rows: distance; cols: top-k):")
    print(acc)
    states, (n_c, n_p), r = best_split(24, M, acc)
    print(f"best split of 24 tree nodes: {n_c} candidates + {n_p} prompt "
          f"tokens, R(T) = {r:.2f} tokens/step")
    bufs = device_buffers(states, M)

    print("== 4. acceptance + speedup vs vanilla ==")
    n_new = 96
    prompts = pipe.val_prompts(4, 32)
    tv = tp = 0.0
    steps_total = 0
    for i in range(4):
        p = jnp.asarray(prompts[i:i + 1])
        t0 = time.time()
        out_p, steps = generate_ppd(params, ppd, cfg, bufs, p, n_new, M)
        tp += time.time() - t0
        steps_total += steps
        # vanilla
        cache = init_cache(cfg, 1, 512)
        t0 = time.time()
        logits, cache, _, _ = forward(params, cfg, p, cache=cache)
        tok = jnp.argmax(logits[:, -1], -1)
        ref = [int(tok[0])]
        sv = jax.jit(lambda c, t: vanilla_decode_step(params, cfg, c, t))
        while len(ref) < n_new:
            cache, tok, _ = sv(cache, tok)
            ref.append(int(tok[0]))
        tv += time.time() - t0
        assert out_p == ref, "PPD output must match vanilla exactly"
    tau = 4 * n_new / steps_total
    print(f"acceptance length tau = {tau:.2f} tokens/step")
    print(f"walltime: vanilla {tv:.1f}s -> PPD {tp:.1f}s "
          f"(speedup {tv / tp:.2f}x; outputs identical)")

    save_checkpoint(args.ckpt, {"ppd": ppd, "acc": acc},
                    {"arch": cfg.name, "m": M, "tau": float(tau)})
    print(f"saved trained prompt tokens -> {args.ckpt}")


if __name__ == "__main__":
    main()
